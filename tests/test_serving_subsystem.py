"""Serving subsystem tests: scheduler refill, KV slot isolation, fused decode.

DESIGN.md §7 invariants:
* the scheduler refills freed slots from the queue (continuous batching);
* a refilled slot cannot observe the previous occupant's KV entries — a
  request's output is identical whether it runs on a fresh engine or in a
  recycled slot;
* the fused int4 decode epilogue (dequant+bias+GELU in-kernel) produces the
  same token stream as the unfused path (the integer accumulators match
  exactly; the f32 epilogue may differ only in last-ulp fusion noise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.packing import quantize_weight
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.serving import Request, Scheduler, ServeMetrics, ServingEngine

KEY = jax.random.PRNGKey(0)


def _engine(slots=2, *, act=None, backend="reference", fuse=None,
            last_k_int4=None, max_len=64, prefill_mode="auto"):
    cfg = reduced(get_config("stablelm-3b"))
    if act is not None:
        cfg = cfg.replace(act=act)
    n = cfg.num_layers
    k4 = n // 2 if last_k_int4 is None else last_k_int4
    pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=k4)
    plan = ExecutionPlan.build(cfg, pol, backend=backend,
                               fuse_epilogue=fuse,
                               prefill_mode=prefill_mode)
    model = deploy(api.init_model(cfg, KEY), plan)
    return ServingEngine(model, slots=slots, max_len=max_len), cfg


# ---------------------------------------------------------------- scheduler

def test_scheduler_refills_freed_slots():
    sch = Scheduler(slots=2)
    reqs = [sch.submit(Request(prompt=np.array([i]), max_new_tokens=1))
            for i in range(5)]
    placed = sch.admit()
    assert [s for s, _ in placed] == [0, 1]
    assert [r.rid for _, r in placed] == [0, 1]
    assert sch.admit() == []                      # table full, no-op
    assert len(sch.queue) == 3

    done = sch.complete(0)                        # slot 0 finishes ...
    assert done is reqs[0]
    placed = sch.admit()                          # ... and refills from queue
    assert placed == [(0, reqs[2])]
    assert sch.num_active == 2 and sch.has_work

    for s in (0, 1):
        sch.complete(s)
    sch.admit()
    for s in (0, 1):
        sch.complete(s)
    assert not sch.has_work
    assert sorted(r.rid for r in sch.done) == [0, 1, 2, 3, 4]
    # drain semantics: pop_done() empties the list (no unbounded growth on
    # a long-lived engine) and is idempotent
    assert sorted(r.rid for r in sch.pop_done()) == [0, 1, 2, 3, 4]
    assert sch.done == [] and sch.pop_done() == []


def test_scheduler_preserves_fifo_order():
    sch = Scheduler(slots=1)
    for i in range(3):
        sch.submit(Request(prompt=np.array([i])))
    order = []
    while sch.has_work:
        for s, r in sch.admit():
            order.append(r.rid)
            sch.complete(s)
    assert order == [0, 1, 2]


# ------------------------------------------------------------ slot isolation

def test_kv_cache_slot_isolation_across_refills():
    """A request decoded in a recycled slot must produce exactly the tokens
    it produces on a fresh engine (per-slot cursors; DESIGN.md §7)."""
    r1 = np.arange(1, 11, dtype=np.int32)         # long, fills cache rows
    r2 = np.array([7, 3, 11, 2], np.int32)

    eng, _ = _engine(slots=1)
    assert eng.prefill_mode == "chunked"
    eng.submit(Request(prompt=r1.copy(), max_new_tokens=6))
    eng.submit(Request(prompt=r2.copy(), max_new_tokens=6))
    eng.run_until_drained()
    recycled = eng.done[1].out

    fresh_eng, _ = _engine(slots=1)
    fresh_eng.submit(Request(prompt=r2.copy(), max_new_tokens=6))
    fresh_eng.run_until_drained()
    fresh = fresh_eng.done[0].out

    np.testing.assert_array_equal(recycled, fresh)


def test_concurrent_slots_match_solo_runs():
    """Requests decoded side-by-side in the slot table produce the same
    tokens as each would alone (no cross-slot leakage)."""
    prompts = [np.array([5, 9, 2], np.int32),
               np.array([8, 8, 1, 4, 12], np.int32)]
    eng, _ = _engine(slots=2)
    for p in prompts:
        eng.submit(Request(prompt=p.copy(), max_new_tokens=5))
    eng.run_until_drained()
    batched = {r.rid: r.out for r in eng.done}

    for i, p in enumerate(prompts):
        solo, _ = _engine(slots=2)
        solo.submit(Request(prompt=p.copy(), max_new_tokens=5))
        solo.run_until_drained()
        np.testing.assert_array_equal(batched[i], solo.done[0].out)


def test_engine_deterministic_and_drains():
    outs = []
    for _ in range(2):
        eng, cfg = _engine(slots=2)
        rng = np.random.default_rng(3)
        for _ in range(5):
            eng.submit(Request(prompt=rng.integers(1, cfg.vocab_size, 6)
                               .astype(np.int32), max_new_tokens=4))
        steps = eng.run_until_drained()
        assert len(eng.done) == 5
        assert all(len(r.out) == 4 for r in eng.done)
        assert steps < 60
        outs.append([r.out.tolist() for r in eng.done])
    assert outs[0] == outs[1]


def test_token_mode_still_supported():
    eng, cfg = _engine(slots=2, prefill_mode="token")
    eng.submit(Request(prompt=np.arange(1, 7, dtype=np.int32),
                       max_new_tokens=3))
    eng.run_until_drained()
    assert len(eng.done) == 1 and len(eng.done[0].out) == 3
    # metrics count every generated token, including the one emitted on the
    # step that consumes the last prompt token
    assert eng.metrics.summary()["decode_tokens"] == 3


def test_request_exceeding_max_len_rejected():
    """Past max_len the cache scatter would drop writes silently; the engine
    must reject the request at submit() instead of degrading quality."""
    eng, _ = _engine(slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=np.arange(1, 11, dtype=np.int32),
                           max_new_tokens=12))


# ------------------------------------------------------- fused decode kernel

def _int4_operands(M=8, K=64, N=32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.05, jnp.float32)
    s_w = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 8.0
    s_a = jnp.asarray(np.float32(np.abs(np.asarray(x)).max() / 8.0))
    wq, _ = quantize_weight(w, s_w, 4)
    b = jnp.asarray(rng.standard_normal((N,)), jnp.float32)
    return x, wq, s_a, s_w, b


def test_fused_epilogue_integer_accumulator_exact():
    """The fused kernel's integer matmul is bit-exact vs the unfused kernel:
    recovering acc = out / (s_a*s_w) from both paths gives the same ints."""
    from repro.kernels import ops
    x, wq, s_a, s_w, _ = _int4_operands()
    unfused = ops.int4_matmul(x, wq, s_a, s_w, a_bits=4)
    fused = ops.int4_matmul(x, wq, s_a, s_w, a_bits=4, act="none")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
    scale = np.asarray(s_a * s_w)
    np.testing.assert_array_equal(np.rint(np.asarray(fused) / scale),
                                  np.rint(np.asarray(unfused) / scale))


def test_fused_epilogue_matches_unfused_composition():
    from repro.kernels import ops
    from repro.models.layers import gelu_f32
    x, wq, s_a, s_w, b = _int4_operands()
    ref = gelu_f32(ops.int4_matmul(x, wq, s_a, s_w, a_bits=4) + b)
    fused = ops.int4_matmul(x, wq, s_a, s_w, a_bits=4, bias=b, act="gelu")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_engine_fused_vs_unfused_token_streams_exact():
    """End-to-end: the engine's decode steps emit the SAME token ids with the
    fused epilogue on or off (exact integer match of the outputs)."""
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8], np.int32)]
    streams = []
    for fuse in (False, True):
        eng, _ = _engine(slots=2, act="gelu", backend="pallas", fuse=fuse,
                         last_k_int4=4)   # all layers int4
        for p in prompts:
            eng.submit(Request(prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained()
        streams.append({r.rid: r.out.tolist() for r in eng.done})
    assert streams[0] == streams[1]


# ------------------------------------------------------------------ metrics

def test_metrics_summary_percentiles():
    m = ServeMetrics()
    for ms in (1.0, 2.0, 3.0, 4.0):
        m.record("decode", ms / 1e3, 2)
    m.record("prefill", 0.01, 7)
    s = m.summary()
    assert s["decode_steps"] == 4
    assert s["decode_tokens"] == 8
    assert s["total_tokens"] == 15
    np.testing.assert_allclose(s["decode_p50_ms"], 2.5)
    assert 3.9 < s["decode_p99_ms"] <= 4.0
    assert s["tokens_per_s"] == pytest.approx(15 / 0.02)
