"""Generation API tests (DESIGN.md §10): sampling, streaming, lifecycle,
admission.

Invariants under test:

* temperature=0 ``GenerationRequest`` streams are byte-identical to the
  legacy greedy ``Request`` path, on both int8 and int4 deployed plans;
* a request's sampled stream is a function of (prompt, seed) only — the
  same tokens whether it runs alone or batched with other requests;
* a stop token ends a request early and demonstrably frees its slot for
  queued work (the queued request admits sooner than the stopped request's
  max_new schedule would allow);
* ``cancel(rid)`` works mid-decode (slot + KV freed, partial output kept)
  and on queued requests (never admitted);
* priority admission orders contended requests; the bounded queue raises
  ``QueueFullError``; expired deadlines shed at admit;
* ``run_until_drained`` raises instead of silently stranding work;
* ``pop_done`` drains; TTFT / queue-wait land in ``ServeMetrics``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.deploy.plan import plan_from_meta, plan_to_meta
from repro.models import api
from repro.serving import (GenerationRequest, QueueFullError, Request,
                           SamplingParams, Scheduler, ServeMetrics,
                           ServingEngine)
from repro.serving.api import sample_token

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("stablelm-3b"))


@pytest.fixture(scope="module")
def fp_setup(cfg):
    """fp params + reference plan — cheap engine for lifecycle tests."""
    plan = ExecutionPlan.build(cfg, None)
    return api.init_model(cfg, KEY), plan


@pytest.fixture(scope="module")
def int_models(cfg):
    """Deployed int8-only and int4-everywhere models (the acceptance pair)."""
    n = cfg.num_layers
    out = {}
    for name, k4 in (("int8", 0), ("int4", n)):
        pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=k4)
        plan = ExecutionPlan.build(cfg, pol, backend="pallas")
        out[name] = deploy(api.init_model(cfg, KEY), plan)
    return out


def _fp_engine(fp_setup, **kw):
    params, plan = fp_setup
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    return ServingEngine(params, plan, **kw)


# ------------------------------------------------------- legacy equivalence

@pytest.mark.parametrize("which", ["int8", "int4"])
def test_temperature_zero_matches_legacy_greedy(int_models, which):
    """Acceptance: a temperature=0 GenerationRequest stream is byte-identical
    to the legacy greedy Request path, per deployed plan."""
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([9, 2, 6], np.int32)]
    model = int_models[which]

    legacy_eng = ServingEngine(model, slots=2, max_len=64)
    for p in prompts:
        legacy_eng.submit(Request(prompt=p.copy(), max_new_tokens=6))
    legacy_eng.run_until_drained()
    legacy = {r.rid: r.out.tolist() for r in legacy_eng.pop_done()}

    new_eng = ServingEngine(model, slots=2, max_len=64)
    streams = [new_eng.submit(GenerationRequest(prompt=p.copy(),
                                                max_new_tokens=6))
              for p in prompts]
    new_eng.run_until_drained()
    for st in streams:
        assert st.finish_reason == "length"
        assert st.tokens == legacy[st.rid]
        np.testing.assert_array_equal(st.request.out, legacy[st.rid])


def test_request_shim_is_a_generation_request():
    r = Request(prompt=np.array([1, 2], np.int32), max_new_tokens=3)
    assert isinstance(r, GenerationRequest)
    assert r.sampling is None and r.stop_tokens == frozenset()
    assert r.priority == 0 and r.deadline_s is None


# ------------------------------------------------------------- determinism

def test_same_seed_deterministic_across_batch_compositions(fp_setup):
    """A sampled stream depends on (prompt, seed) only: identical whether
    the request runs alone or alongside other requests (per-slot PRNG keys,
    not per-batch)."""
    def target():
        return GenerationRequest(
            prompt=np.array([5, 9, 2], np.int32), max_new_tokens=8,
            sampling=SamplingParams(temperature=1.2, top_k=64, seed=11))

    solo = _fp_engine(fp_setup, slots=3)
    alone = solo.submit(target()).result().tokens.tolist()

    crowded = _fp_engine(fp_setup, slots=3)
    rng = np.random.default_rng(0)
    for seed in (1, 2):     # different seeds/prompts sharing the batch
        crowded.submit(GenerationRequest(
            prompt=rng.integers(1, 200, 5).astype(np.int32),
            max_new_tokens=8,
            sampling=SamplingParams(temperature=0.7, seed=seed)))
    batched = crowded.submit(target()).result().tokens.tolist()
    assert batched == alone


def test_different_seeds_diverge(fp_setup):
    streams = []
    for seed in (0, 12345):
        eng = _fp_engine(fp_setup)
        st = eng.submit(GenerationRequest(
            prompt=np.array([5, 9, 2], np.int32), max_new_tokens=16,
            sampling=SamplingParams(temperature=2.0, seed=seed)))
        streams.append(st.result().tokens.tolist())
    assert streams[0] != streams[1]


def test_token_mode_sampling_deterministic(cfg):
    """Token-mode (shared-cursor) engines sample through the same jitted
    step: per-request determinism holds there too."""
    plan = ExecutionPlan.build(cfg, None, prefill_mode="token")
    params = api.init_model(cfg, KEY)
    outs = []
    for _ in range(2):
        eng = ServingEngine(params, plan, slots=2, max_len=64)
        st = eng.submit(GenerationRequest(
            prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=5,
            sampling=SamplingParams(temperature=0.9, seed=4)))
        outs.append(st.result().tokens.tolist())
    assert outs[0] == outs[1]


# ------------------------------------------------------- stop + cancellation

def test_stop_token_frees_slot_for_queued_work(fp_setup):
    """Acceptance: a stop-token request releases its slot early — the queued
    request admits and the whole drain takes far fewer steps than the
    stopped request's max_new schedule alone would."""
    prompt = np.array([5, 9, 2], np.int32)
    probe = _fp_engine(fp_setup, slots=1)
    first = list(probe.submit(GenerationRequest(prompt=prompt.copy(),
                                                max_new_tokens=1)))[0]

    eng = _fp_engine(fp_setup, slots=1)
    stopper = eng.submit(GenerationRequest(
        prompt=prompt.copy(), max_new_tokens=32, stop_tokens={first}))
    queued = eng.submit(GenerationRequest(
        prompt=np.array([7, 7, 7], np.int32), max_new_tokens=3))
    steps = eng.run_until_drained()

    assert stopper.finish_reason == "stop"
    assert stopper.tokens == [first]            # stopped on its FIRST token
    assert queued.finish_reason == "length" and len(queued.tokens) == 3
    # a full 32-token schedule needs > 32 steps before the queued request
    # even admits; the stop released the slot almost immediately
    assert steps < 8, steps
    assert queued.request.queue_wait_s is not None


def test_cancel_mid_decode_frees_slot_and_keeps_partial(fp_setup):
    eng = _fp_engine(fp_setup, slots=1)
    victim = eng.submit(GenerationRequest(
        prompt=np.arange(1, 7, dtype=np.int32), max_new_tokens=32))
    queued = eng.submit(GenerationRequest(
        prompt=np.array([3, 1, 4], np.int32), max_new_tokens=3))
    eng.engine_step()        # prefill (token 1) + batched decode (token 2)
    eng.engine_step()        # one more decode step (token 3)
    assert len(victim.tokens) == 3 and not victim.finished

    assert eng.cancel(victim.rid)
    assert victim.finished and victim.finish_reason == "cancelled"
    assert victim.request.out.tolist() == victim.tokens    # partial kept
    assert eng.scheduler.num_active == 0                   # slot freed
    if eng.kv is not None:
        assert eng.kv.lengths()[0] == 0                    # KV state freed

    eng.run_until_drained()               # queued request takes the slot
    assert queued.finish_reason == "length"
    assert len(queued.tokens) == 3
    assert not eng.cancel(victim.rid)     # already finished: no-op


def test_callback_cancel_of_other_request_mid_step(fp_setup):
    """An on_token callback cancelling ANOTHER active request must not crash
    the emit loop iterating the pre-cancel slot snapshot (reentrancy)."""
    eng = _fp_engine(fp_setup, slots=2)
    victim = eng.submit(GenerationRequest(
        prompt=np.array([9, 9, 9], np.int32), max_new_tokens=32))
    trigger = eng.submit(GenerationRequest(
        prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
        on_token=lambda rid, tok: (len(trigger.tokens) == 2
                                   and eng.cancel(victim.rid)))
    eng.run_until_drained()
    assert trigger.finish_reason == "length" and len(trigger.tokens) == 4
    assert victim.finish_reason == "cancelled"
    assert len(victim.tokens) < 32


def test_callback_self_cancel_mid_step(fp_setup):
    """A request cancelling ITSELF from its own callback must not double-
    complete its slot."""
    eng = _fp_engine(fp_setup, slots=1)
    st = eng.submit(GenerationRequest(
        prompt=np.array([4, 5, 6], np.int32), max_new_tokens=32))
    st.on_token = lambda rid, tok: (len(st.tokens) == 3
                                    and eng.cancel(rid))
    eng.run_until_drained()
    assert st.finish_reason == "cancelled"
    assert len(st.tokens) == 3
    assert eng.scheduler.num_active == 0


def test_queued_cancel_removes_heap_entry_under_full_slots(fp_setup):
    """Cancelling queued requests while every slot is busy must free their
    queue entries immediately (no tombstone leak past max_queue)."""
    eng = _fp_engine(fp_setup, slots=1, max_queue=2)
    eng.submit(GenerationRequest(prompt=np.array([1], np.int32),
                                 max_new_tokens=16))
    eng.engine_step()                     # occupy the only slot
    for _ in range(5):                    # churn: submit + cancel, no admits
        st = eng.submit(GenerationRequest(prompt=np.array([2], np.int32),
                                          max_new_tokens=1))
        assert eng.cancel(st.rid)
    assert eng.scheduler.queue_depth == 0
    assert len(eng.scheduler._heap) == 0  # entries gone, not tombstoned
    eng.run_until_drained()


def test_cancel_queued_request_never_runs(fp_setup):
    eng = _fp_engine(fp_setup, slots=1)
    running = eng.submit(GenerationRequest(
        prompt=np.array([1, 2, 3], np.int32), max_new_tokens=2))
    queued = eng.submit(GenerationRequest(
        prompt=np.array([4, 5, 6], np.int32), max_new_tokens=2))
    assert eng.cancel(queued.rid)
    assert queued.finish_reason == "cancelled"
    assert queued.request.out.tolist() == []
    eng.run_until_drained()
    assert running.finish_reason == "length"
    rids = [r.rid for r in eng.pop_done()]
    assert queued.rid in rids and running.rid in rids
    assert eng.cancel(999) is False


# ----------------------------------------------------------------- admission

def test_priority_ordering_under_contention(fp_setup):
    """With one slot and a full queue, higher priority admits first; FIFO
    within a priority level."""
    eng = _fp_engine(fp_setup, slots=1)
    reqs = {}
    for name, pri in (("low1", 0), ("low2", 0), ("high", 5), ("mid", 2)):
        reqs[name] = eng.submit(GenerationRequest(
            prompt=np.array([1, 2], np.int32), max_new_tokens=1,
            priority=pri))
    eng.run_until_drained()
    order = [r.rid for r in eng.pop_done()]
    # all four are queued before the first engine step, so pure priority
    # decides the single slot; low1 beats low2 by FIFO within the level
    assert order == [reqs["high"].rid, reqs["mid"].rid,
                     reqs["low1"].rid, reqs["low2"].rid]


def test_bounded_queue_backpressure(fp_setup):
    eng = _fp_engine(fp_setup, slots=1, max_queue=2)
    eng.submit(GenerationRequest(prompt=np.array([1], np.int32),
                                 max_new_tokens=1))
    eng.submit(GenerationRequest(prompt=np.array([2], np.int32),
                                 max_new_tokens=1))
    with pytest.raises(QueueFullError, match="queue full"):
        eng.submit(GenerationRequest(prompt=np.array([3], np.int32),
                                     max_new_tokens=1))
    eng.run_until_drained()               # draining frees queue room
    eng.submit(GenerationRequest(prompt=np.array([3], np.int32),
                                 max_new_tokens=1))
    eng.run_until_drained()
    assert len(eng.pop_done()) == 3


def test_deadline_shedding_scheduler_level():
    """Fake-clock scheduler: a request whose deadline elapsed before a slot
    freed is shed at admit, not decoded."""
    now = [0.0]
    sch = Scheduler(1, clock=lambda: now[0])
    fresh = sch.submit(GenerationRequest(prompt=np.array([1], np.int32)))
    stale = sch.submit(GenerationRequest(prompt=np.array([2], np.int32),
                                         deadline_s=5.0))
    placed = sch.admit()                  # fresh takes the only slot
    assert [r.rid for _, r in placed] == [fresh.rid]
    now[0] = 10.0                         # stale's deadline passes in queue
    sch.complete(0)
    assert sch.admit() == []              # stale shed, nothing placed
    assert [r.rid for r in sch.pop_shed()] == [stale.rid]
    assert not sch.has_work


def test_deadline_shedding_engine_finalizes(fp_setup):
    eng = _fp_engine(fp_setup, slots=1)
    running = eng.submit(GenerationRequest(
        prompt=np.array([1, 2, 3], np.int32), max_new_tokens=2))
    doomed = eng.submit(GenerationRequest(
        prompt=np.array([4, 5, 6], np.int32), max_new_tokens=2,
        deadline_s=0.0))                  # expires before any admit
    eng.run_until_drained()
    assert doomed.finished and doomed.finish_reason == "shed"
    assert doomed.request.out.tolist() == []
    assert running.finish_reason == "length"
    assert {r.rid for r in eng.pop_done()} == {running.rid, doomed.rid}


def test_run_until_drained_raises_on_stranded_work(fp_setup):
    eng = _fp_engine(fp_setup, slots=1)
    for i in range(3):
        eng.submit(GenerationRequest(prompt=np.array([i + 1], np.int32),
                                     max_new_tokens=8))
    with pytest.raises(RuntimeError, match=r"stranded"):
        eng.run_until_drained(max_steps=2)
    eng.run_until_drained()               # recoverable: finish the rest
    assert len(eng.pop_done()) == 3


# ---------------------------------------------------------------- streaming

def test_token_stream_iterator_and_callback_agree(fp_setup):
    eng = _fp_engine(fp_setup)
    got = []
    st = eng.submit(GenerationRequest(prompt=np.array([5, 9], np.int32),
                                      max_new_tokens=5),
                    on_token=lambda rid, tok: got.append((rid, tok)))
    iterated = list(st)                   # pumps engine_step under the hood
    assert len(iterated) == 5
    assert got == [(st.rid, t) for t in iterated]
    assert st.request.out.tolist() == iterated
    assert st.result().finish_reason == "length"   # result() after finish


def test_engine_step_returns_emitted_pairs(fp_setup):
    eng = _fp_engine(fp_setup, slots=2)
    a = eng.submit(GenerationRequest(prompt=np.array([1, 2], np.int32),
                                     max_new_tokens=3))
    b = eng.submit(GenerationRequest(prompt=np.array([3, 4], np.int32),
                                     max_new_tokens=3))
    events = []
    while eng.scheduler.has_work:
        events.extend(eng.engine_step())
    by_rid = {a.rid: [], b.rid: []}
    for rid, tok in events:
        by_rid[rid].append(tok)
    assert by_rid[a.rid] == a.tokens and by_rid[b.rid] == b.tokens


# ------------------------------------------------------------------ metrics

def test_metrics_ttft_and_queue_wait(fp_setup):
    eng = _fp_engine(fp_setup, slots=1)
    for i in range(3):
        eng.submit(GenerationRequest(prompt=np.array([i + 1, 2], np.int32),
                                     max_new_tokens=2))
    eng.run_until_drained()
    s = eng.metrics.summary()
    assert s["ttft_n"] == 3 and s["queue_wait_n"] == 3
    assert s["ttft_p50_ms"] >= 0 and s["ttft_p99_ms"] >= s["ttft_p50_ms"]
    # queueing time must not inflate busy-time throughput
    assert s["tokens_per_s"] > 0


def test_metrics_wait_percentile_math():
    m = ServeMetrics()
    for v in (1.0, 2.0, 3.0, 4.0):
        m.record_wait("ttft", v / 1e3)
    m.record_wait("queue_wait", 0.01)
    s = m.summary()
    assert s["ttft_n"] == 4
    np.testing.assert_allclose(s["ttft_p50_ms"], 2.5)
    assert 3.9 < s["ttft_p99_ms"] <= 4.0
    # lone sample: reported as every percentile (sub-2-sample guard)
    assert s["queue_wait_p50_ms"] == s["queue_wait_p99_ms"] == 10.0
    assert "ttft" in m.report() and "queue_wait" in m.report()


# ------------------------------------------------------------ sampling math

def test_sample_token_temperature_zero_is_exact_argmax():
    rng = np.random.default_rng(0)
    for _ in range(5):
        logits = rng.standard_normal(128).astype(np.float32)
        tok = int(sample_token(logits, 0, 0, 0.0, 0, 1.0))
        assert tok == int(np.argmax(logits))


def test_sample_token_top_k_restricts_support():
    rng = np.random.default_rng(1)
    logits = rng.standard_normal(64).astype(np.float32)
    topk = set(np.argsort(-logits)[:5].tolist())
    draws = {int(sample_token(logits, 7, step, 1.5, 5, 1.0))
             for step in range(40)}
    assert draws <= topk and len(draws) > 1


def test_sample_token_top_k_one_is_argmax():
    rng = np.random.default_rng(2)
    logits = rng.standard_normal(64).astype(np.float32)
    for step in range(5):
        assert int(sample_token(logits, 3, step, 2.0, 1, 1.0)) == \
            int(np.argmax(logits))


def test_sample_token_top_p_keeps_nucleus():
    # one dominant logit: its probability mass alone exceeds top_p, so the
    # nucleus is that single token at any temperature
    logits = np.full(32, -5.0, np.float32)
    logits[17] = 10.0
    for step in range(10):
        assert int(sample_token(logits, 9, step, 1.0, 0, 0.5)) == 17


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="max_new_tokens"):
        GenerationRequest(prompt=np.array([1], np.int32), max_new_tokens=0)
    assert SamplingParams.resolve(None) == SamplingParams()
    assert SamplingParams.resolve({"temperature": 0.5}).temperature == 0.5


# ---------------------------------------------------------- plan integration

def test_plan_sampling_defaults_resolved_at_build_and_roundtrip(cfg):
    plan = ExecutionPlan.build(
        cfg, None, sampling={"temperature": 0.7, "top_k": 30, "seed": 9})
    assert plan.default_sampling == SamplingParams(temperature=0.7,
                                                   top_k=30, seed=9)
    rebuilt = plan_from_meta(plan_to_meta(plan))
    assert rebuilt.default_sampling == plan.default_sampling
    assert rebuilt == plan

    # requests without explicit sampling inherit the plan default
    eng = ServingEngine(api.init_model(cfg, KEY), plan, slots=1, max_len=64)
    st = eng.submit(GenerationRequest(prompt=np.array([1, 2], np.int32),
                                      max_new_tokens=2))
    assert st.request.sampling == plan.default_sampling
    # legacy-meta plans (no sampling key) resolve to greedy defaults
    meta = plan_to_meta(ExecutionPlan.build(cfg, None))
    del meta["build"]["sampling"]
    assert plan_from_meta(meta).default_sampling == SamplingParams()
