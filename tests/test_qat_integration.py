"""End-to-end QAT pipeline: calibrate -> QAT -> deploy -> int parity.

Also the paper-shaped system behaviours: MSE vs STE scale training reduces
quantization error; mixed 4/8 segments; distillation losses flow.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import qat
from repro.core.distill import combine_losses, minilm_losses, output_loss
from repro.core.policy import QuantPolicy
from repro.core.quantizer import lsq_quantize
from repro.models import api
from repro.models.bert import bert_classify_logits, classification_loss

KEY = jax.random.PRNGKey(0)


def _calibrated(arch="tinybert4", mode="fake", last_k=2):
    cfg = reduced(get_config(arch))
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode=mode, last_k_int4=last_k)
    segs = api.segments_for(cfg, pol)
    params = api.init_model(cfg, KEY)
    params = qat.calibrate_weight_scales(params, qat.default_bits_fn(cfg, pol))
    inputs = {"tokens": jax.random.randint(KEY, (2, 16), 1, cfg.vocab_size)}
    fp_segs = [(s, e, sp.with_mode("none")) for s, e, sp in segs]
    fwd = lambda p, b: api.forward(p, cfg, fp_segs, **b)[0]
    params = qat.calibrate_act_scales(params, cfg, pol, fwd, [inputs])
    return cfg, pol, segs, params, inputs


def test_calibration_sets_scales():
    cfg, pol, segs, params, _ = _calibrated()
    s_w = params["layers"]["attn"]["wq"]["s_w"]
    s_a = params["layers"]["attn"]["wq"]["s_a"]
    assert np.all(np.asarray(s_w) > 0) and np.all(np.asarray(s_w) < 1.0)
    assert np.all(np.asarray(s_a) > 0)
    # int4 layers (last k) must have LARGER weight scales than if int8
    w = np.asarray(params["layers"]["attn"]["wq"]["w"])
    expected_4 = np.abs(w[-1]).max(axis=0) / 8
    np.testing.assert_allclose(np.asarray(s_w[-1, 0]), expected_4, rtol=1e-5)
    expected_8 = np.abs(w[0]).max(axis=0) / 127
    np.testing.assert_allclose(np.asarray(s_w[0, 0]), expected_8, rtol=1e-5)


def test_deploy_int_parity_all_segments():
    cfg, _, _, params, inputs = _calibrated()
    n = cfg.num_layers
    for mode_pair in [(0, "all-int8"), (n // 2, "mixed"), (n, "all-int4")]:
        k4, _name = mode_pair
        pf = QuantPolicy(num_layers=n, mode="fake", last_k_int4=k4)
        pi = QuantPolicy(num_layers=n, mode="int", last_k_int4=k4)
        segs_f = api.segments_for(cfg, pf)
        segs_i = api.segments_for(cfg, pi)
        lf, *_ = api.forward(params, cfg, segs_f, **inputs)
        dep = qat.deploy_params(params, cfg, segs_i)
        li, *_ = api.forward(dep, cfg, segs_i, **inputs)
        rel = float(jnp.max(jnp.abs(lf - li)) / (jnp.max(jnp.abs(lf)) + 1e-9))
        assert rel < 1e-4, (mode_pair, rel)


def test_mse_scale_training_reduces_quant_error():
    """Train ONLY the scale with each grad mode on a fixed tensor: the
    MSE-mode scale must (at least) match STE at reducing ||Q[x]-x||^2."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))

    def err(s):
        q = lsq_quantize(x, jnp.float32(s), 4, "mse")
        return float(jnp.mean((q - x) ** 2))

    results = {}
    for mode, lr in [("mse", 0.05), ("ste", 0.05)]:
        s = jnp.float32(1.0)   # poor init (optimal ~ max|x|/8 ~ 0.45)
        for _ in range(100):
            g = jax.grad(lambda s_: jnp.sum(lsq_quantize(x, s_, 4, mode)))(s)
            s = jnp.maximum(s - lr * g, 1e-4)
        results[mode] = err(float(s))
    assert results["mse"] <= err(1.0), "MSE mode must improve over init"
    assert results["mse"] <= results["ste"] * 1.05


def test_distill_losses_and_deeper_teacher():
    cfg_s = reduced(get_config("tinybert4"))
    cfg_t = reduced(get_config("bert-base")).replace(
        num_layers=8, d_model=128, num_heads=8, num_kv_heads=8)
    ps = api.init_model(cfg_s, KEY)
    pt = api.init_model(cfg_t, jax.random.fold_in(KEY, 1))
    segs_s = api.segments_for(cfg_s, _pol(cfg_s))
    segs_t = api.segments_for(cfg_t, None)
    toks = jax.random.randint(KEY, (2, 12), 1, 200)
    ls, _, taps_s, _ = api.forward(ps, cfg_s, segs_s, tokens=toks,
                                   want_taps=True)
    lt, _, taps_t, _ = api.forward(pt, cfg_t, segs_t, tokens=toks,
                                   want_taps=True)
    # relation heads bridge different widths/head-counts (MiniLM-v2 style)
    l_attn, l_val = minilm_losses(taps_s, taps_t, num_relation_heads=4)
    l_out = output_loss(ls[..., :200], lt[..., :200])
    total, parts = combine_losses(jnp.float32(1.0), l_out, l_attn, l_val,
                                  alpha=10.0, beta=1.0)
    for k, v in parts.items():
        assert np.isfinite(float(v)), k
    assert float(total) > 0
    # gradients flow into the student only
    g = jax.grad(lambda p: minilm_losses(
        api.forward(p, cfg_s, segs_s, tokens=toks, want_taps=True)[2],
        jax.lax.stop_gradient(taps_t), 4)[0])(ps)
    gn = np.sqrt(sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g)))
    assert gn > 0


def _pol(cfg, mode="fake"):
    n = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    return QuantPolicy(num_layers=n, mode=mode, last_k_int4=n // 2)


def test_qat_classification_learns():
    """TinyBERT-shaped student + QAT on a learnable synthetic task."""
    from repro.data import classification_batches
    cfg = reduced(get_config("tinybert4")).replace(num_layers=2)
    from repro.models.bert import init_bert_classifier
    pol = QuantPolicy(num_layers=2, mode="fake", last_k_int4=1)
    segs = api.segments_for(cfg, pol)
    params = init_bert_classifier(cfg, 2, KEY)
    data = classification_batches(cfg.vocab_size, 16, 32, num_classes=2,
                                  prefetch=False)

    @jax.jit
    def step(p, toks, labels):
        def loss_fn(pp):
            logits, _ = bert_classify_logits(pp, cfg, segs, toks)
            return classification_loss(logits, labels)
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - 0.002 * b, p, g), l

    it = iter(data)
    losses = []
    for i in range(40):
        b = next(it)
        params, l = step(params, jnp.asarray(b["tokens"]),
                         jnp.asarray(b["labels"]))
        losses.append(float(l))
    # compare averaged windows (single-batch CE is noisy)
    first = sum(losses[:8]) / 8
    last = sum(losses[-8:]) / 8
    assert last < first, (first, last)
