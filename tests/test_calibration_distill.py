"""Calibration statistics + distillation math unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.calibration import ActCalibrator, weight_scale
from repro.core.distill import (hidden_state_loss, kl_from_logits,
                                output_loss, relation_distribution)
from repro.core.quantizer import qrange


def test_weight_scale_per_row():
    w = jnp.array([[1.0, -2.0], [0.5, 4.0], [0.1, 0.2]])
    s = weight_scale(w, 4, axis=1)  # per out-channel (columns)
    np.testing.assert_allclose(np.asarray(s).ravel(), [1.0 / 8, 4.0 / 8],
                               rtol=1e-6)
    s_t = weight_scale(w, 4, axis=None)
    np.testing.assert_allclose(float(s_t), 0.5, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.floats(1.0, 100.0))
def test_act_calibrator_percentile(nb, scale):
    """Calibrated scale tracks the top-0.01% |a| (paper §3.1)."""
    cal = ActCalibrator(samples_per_batch=2048, seed=1)
    rng = np.random.default_rng(0)
    for i in range(nb):
        cal.update(jnp.asarray(rng.standard_normal(4096).astype(np.float32)
                               * scale))
    s = float(cal.scale(8))
    _, qmax = qrange(8)
    # 99.99th pct of N(0, scale) ~ 3.9 * scale; reservoir gives it loosely
    assert 2.0 * scale / qmax < s < 5.5 * scale / qmax


def test_kl_zero_for_identical():
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((4, 7)).astype(np.float32))
    assert float(kl_from_logits(logits, logits)) == pytest.approx(0, abs=1e-6)
    shifted = logits + 3.0  # softmax-invariant
    assert float(kl_from_logits(logits, shifted)) == pytest.approx(0, abs=1e-5)


def test_kl_positive_and_masked():
    a = jnp.array([[0.0, 0.0, 5.0]])
    b = jnp.array([[5.0, 0.0, 0.0]])
    assert float(kl_from_logits(a, b)) > 1.0
    m = jnp.array([0.0])
    assert float(kl_from_logits(a, b, m)) == 0.0


def test_relation_distribution_shapes_and_masking():
    B, S, D, R = 2, 5, 8, 4
    a = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((B, S, D)).astype(np.float32))
    logits = relation_distribution(a, a, R)
    assert logits.shape == (B, R, S, S)
    mask = jnp.ones((B, S)).at[:, -2:].set(0)
    masked = relation_distribution(a, a, R, mask)
    probs = jax.nn.softmax(masked, -1)
    assert float(jnp.max(probs[..., -2:])) < 1e-6


def test_output_and_hidden_losses():
    x = jnp.ones((2, 3, 4))
    assert float(output_loss(x, x)) == 0.0
    assert float(output_loss(x, x + 1)) == pytest.approx(1.0)
    assert float(hidden_state_loss(x, x + 2)) == pytest.approx(4.0)


def test_calibration_mode_collects_in_order():
    from repro.core import calibration
    from repro.models.layers import QuantSpec, init_linear, qlinear
    p = init_linear(jax.random.PRNGKey(0), 8, 8, bias=False)
    x = jnp.ones((2, 8))
    with calibration.calibration_mode() as cm:
        qlinear(x, p, QuantSpec())
        qlinear(2 * x, p, QuantSpec())
    assert len(cm.records) == 2
    assert cm.records[1] == pytest.approx(2 * cm.records[0])
    assert not calibration.active()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_kl_nonnegative_property(seed):
    """KL(P||Q) >= 0 for arbitrary logit pairs (hypothesis sweep)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((3, 9)).astype(np.float32) * 4)
    b = jnp.asarray(rng.standard_normal((3, 9)).astype(np.float32) * 4)
    assert float(kl_from_logits(a, b)) >= -1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500))
def test_pack_roundtrip_property(seed):
    """pack/unpack int4 is lossless for every code in the paper grid."""
    from repro.core.packing import pack_int4, unpack_int4
    rng = np.random.default_rng(seed)
    shape = (2 * int(rng.integers(1, 16)), int(rng.integers(1, 16)))
    codes = jnp.asarray(rng.integers(-7, 9, size=shape).astype(np.int8))
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(codes, axis=0), axis=0)),
        np.asarray(codes))
