"""Fuzz tests for PrefixCache accounting (DESIGN.md §11/§12).

Random match/gather/release/insert sequences over a family of overlapping
prompts, with the byte budget small enough that eviction is constantly
active. After EVERY operation:

* **byte accounting** — ``cache.bytes`` equals the recomputed sum of every
  resident entry's ``nbytes`` (the budget/eviction arithmetic never drifts).
* **refcounts** — every entry's ``refs`` equals the model's count of
  outstanding pins for that key, and refcounts return to exactly zero once
  every match is released.
* **pin safety** — a pinned block is NEVER evicted, no matter how far
  inserts push the cache over budget; once nothing is pinned, the cache is
  back under budget (overshoot is transient by construction).

Driven by a seeded numpy RNG (always runs) and by hypothesis (skips cleanly
without it, runs in CI).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving.prefix_cache import PrefixCache

BLOCK = 4
#: bytes of one cached block with the _rows_fn layout below: a (2, B, 3)
#: int32 rows array plus the B int32 defense-in-depth tokens
BLOCK_BYTES = 2 * BLOCK * 3 * 4 + BLOCK * 4


def _rows_fn(prompt):
    """Deterministic fake KV rows: a pure function of the tokens, so any
    re-insert of the same block is byte-identical."""
    def rows_for_block(lo, hi):
        blk = np.asarray(prompt[lo:hi], np.int32)
        return {"kv": np.tile(blk.reshape(1, -1, 1), (2, 1, 3))}
    return rows_for_block


def _prompt(families, fam, length):
    """A prompt sharing its leading tokens with family ``fam`` — overlap is
    what makes chained block keys collide/extend across operations."""
    base = families[fam % len(families)]
    length = 2 + length % (len(base) - 1)
    return base[:length]


def _check(cache, pins):
    recomputed = sum(e.nbytes for e in cache._entries.values())
    assert cache.bytes == recomputed, (
        f"tracked {cache.bytes} != recomputed {recomputed}")
    for k, n in pins.items():
        if n > 0:
            assert k in cache._entries, "pinned block was evicted"
    for k, e in cache._entries.items():
        assert e.refs == pins.get(k, 0), (
            f"refcount drift: entry {e.refs}, model {pins.get(k, 0)}")
        assert e.refs >= 0
    if not any(n > 0 for n in pins.values()):
        assert cache.bytes <= cache.budget, (
            "over budget with nothing pinned")


def _run_ops(ops, budget_blocks=5):
    rng_fam = np.random.default_rng(0)
    families = [rng_fam.integers(1, 50, 24).astype(np.int32)
                for _ in range(3)]
    cache = PrefixCache(budget_bytes=budget_blocks * BLOCK_BYTES, block=BLOCK)
    pins = {}                       # key -> outstanding pin count (model)
    outstanding = []                # (keys, prompt, m) awaiting release
    for code, fam, length in ops:
        code = code % 4
        prompt = _prompt(families, fam, length)
        if code == 0:                                       # match (pins)
            m, keys = cache.match(prompt)
            assert m % BLOCK == 0 and m <= len(prompt) - 1
            assert m == BLOCK * len(keys)
            for k in keys:
                pins[k] = pins.get(k, 0) + 1
            if keys:
                outstanding.append((keys, prompt, m))
        elif code == 1 and outstanding:                     # gather + check
            keys, p, m = outstanding[length % len(outstanding)]
            g = cache.gather(keys)
            assert g["kv"].shape[1] == m
            assert np.array_equal(g["kv"][0, :, 0], p[:m])
        elif code == 2 and outstanding:                     # release
            keys, _, _ = outstanding.pop(length % len(outstanding))
            cache.release(keys)
            for k in keys:
                pins[k] -= 1
        elif code == 3:                                     # insert
            upto = (length % (len(prompt) // BLOCK + 1)) * BLOCK
            cache.insert(prompt, upto, _rows_fn(prompt))
        _check(cache, pins)
    # drain: release everything still pinned
    for keys, _, _ in outstanding:
        cache.release(keys)
        for k in keys:
            pins[k] -= 1
    _check(cache, pins)
    assert all(n == 0 for n in pins.values())
    assert all(e.refs == 0 for e in cache._entries.values())
    assert cache.bytes <= cache.budget


# ------------------------------------------------------- randomized driver
@pytest.mark.parametrize("seed", range(10))
def test_random_cache_ops_preserve_accounting(seed):
    rng = np.random.default_rng(seed)
    ops = list(zip(rng.integers(0, 4, 250).tolist(),
                   rng.integers(0, 3, 250).tolist(),
                   rng.integers(0, 64, 250).tolist()))
    _run_ops(ops, budget_blocks=3 + seed % 4)


@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2),
                              st.integers(0, 63)), max_size=120))
@settings(max_examples=60, deadline=None)
def test_hypothesis_cache_ops_preserve_accounting(ops):
    _run_ops(ops)


# ----------------------------------------------------------- directed cases
def test_pinned_block_survives_budget_pressure():
    families = [np.arange(1, 25, dtype=np.int32) + 100 * i for i in range(4)]
    cache = PrefixCache(budget_bytes=BLOCK_BYTES, block=BLOCK)
    p0 = families[0]
    cache.insert(p0, BLOCK, _rows_fn(p0))
    m, keys = cache.match(p0)
    assert m == BLOCK and len(keys) == 1
    # shrink the budget under the pinned entry: it alone overshoots now, and
    # every unpinned insert is evicted the moment it lands
    cache.budget = BLOCK_BYTES - 1
    for p in families[1:]:
        cache.insert(p, 2 * BLOCK, _rows_fn(p))
        assert keys[0] in cache._entries       # pinned entry must stay
    assert cache.bytes > cache.budget          # transient overshoot, pinned
    cache.release(keys)
    assert cache.bytes <= cache.budget         # eviction caught up
    assert all(e.refs == 0 for e in cache._entries.values())


def test_match_never_covers_last_prompt_token():
    p = np.arange(1, 2 * BLOCK + 1, dtype=np.int32)   # exactly 2 blocks
    cache = PrefixCache(budget_bytes=10 * BLOCK_BYTES, block=BLOCK)
    cache.insert(p, 2 * BLOCK, _rows_fn(p))
    m, keys = cache.match(p)
    # the last token must be computed for first-step logits: only block 0
    assert m == BLOCK and len(keys) == 1
    cache.release(keys)
