"""Fuzz tests for BlockPool accounting (DESIGN.md §15).

Random admit/release/publish/fork/match sequences over a family of
overlapping prompts, with the block budget small enough that eviction is
constantly active. The device buffers are never written here — the fuzz
targets the HOST bookkeeping the paged engine trusts: free list, refcounts,
per-request block tables, the prefix registry and the one-budget
admission/eviction arithmetic. After EVERY operation:

* **byte accounting** — ``kv_bytes_in_use`` equals ``block_bytes`` times
  the recomputed union of blocks reachable from live tables and the
  registry (tracked bytes never drift from the tables);
* **reachability** — every non-free block is reachable from a live table
  or the registry, and ``free + in_use == num_blocks`` (no leaks, no
  double-frees);
* **refcounts** — every block's refcount equals the model's count of live
  tables holding it, and refcounts drain to exactly zero once every
  request releases;
* **sharing discipline** — a block reachable from two live requests is
  ALWAYS in ``pool.shared`` (attached by reference: a prefix hit or a
  copy-on-write fork share — never an aliasing bug);
* **pin safety** — a block with refcount > 0 (in-flight request) is never
  on the free list and never evicted, no matter the budget pressure.

Driven by a seeded numpy RNG (always runs) and by hypothesis (skips
cleanly without it).
"""
from collections import Counter

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.kernels.kv_pack import kv_row_bytes
from repro.serving.block_pool import BlockPool, blocks_needed
from repro.serving.prefix_cache import PREFIX_BLOCK

B = PREFIX_BLOCK


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


def _block_bytes(cfg):
    return B * cfg.num_layers * kv_row_bytes(cfg.num_kv_heads, cfg.hd, 16,
                                             fp_bytes=4)


def _pool(budget_blocks):
    cfg = _cfg()
    return BlockPool(cfg, budget_blocks * _block_bytes(cfg))


def _prompt(families, fam, length):
    """A prompt sharing its leading tokens with family ``fam`` — overlap is
    what makes registry chains collide/extend across operations."""
    base = families[fam % len(families)]
    length = 2 + length % (len(base) - 1)
    return base[:length]


def _check(pool, tables):
    NB = pool.num_blocks
    free = set(pool._free)
    assert len(free) == len(pool._free), "duplicate block on the free list"
    reachable = (set(b for t in tables.values() for b in t)
                 | set(pool._registry.values()))
    assert set(range(NB)) - free == reachable, (
        "non-free blocks != union(live tables, registry)")
    st = pool.stats()
    assert st["kv_bytes_in_use"] == len(reachable) * pool.block_nbytes
    assert len(pool._free) + pool.blocks_in_use() == NB
    held = Counter(b for t in tables.values() for b in t)
    for b in range(NB):
        assert pool.refs[b] == held.get(b, 0), (
            f"refcount drift at block {b}: pool {pool.refs[b]}, "
            f"model {held.get(b, 0)}")
    for b, n in held.items():
        assert b not in free, "pinned block on the free list"
        holders = sum(1 for t in tables.values() if b in t)
        if holders >= 2:
            assert b in pool.shared, (
                f"block {b} reachable from {holders} live requests but "
                "not marked shared")
    # pool's own tables mirror the model exactly
    assert {r: list(t) for r, t in pool._tables.items()} == tables


def _run_ops(ops, budget_blocks=6):
    rng_fam = np.random.default_rng(0)
    families = [rng_fam.integers(1, 50, 24).astype(np.int32)
                for _ in range(3)]
    pool = _pool(budget_blocks)
    tables: dict[int, list[int]] = {}       # rid -> block ids (model)
    prompts: dict[int, np.ndarray] = {}
    next_rid = 0
    for code, fam, length in ops:
        code = code % 5
        live = sorted(tables)
        if code == 0:                                       # admit
            prompt = _prompt(families, fam, length)
            need = blocks_needed(len(prompt), 1 + length % 6)
            if pool.available() >= need:
                rid, next_rid = next_rid, next_rid + 1
                m, ids = pool.match(prompt)
                pool.attach(rid, ids)
                own = pool.alloc(rid, need - len(ids))
                tables[rid] = list(ids) + own
                prompts[rid] = prompt
        elif code == 1 and live:                            # finish/release
            rid = live[length % len(live)]
            pool.release(rid)
            del tables[rid], prompts[rid]
        elif code == 2 and live:                            # publish
            rid = live[length % len(live)]
            p = prompts[rid]
            pool.publish(rid, p, (len(p) // B) * B)
        elif code == 3 and live:                            # COW fork
            leader = live[length % len(live)]
            p = prompts[leader]
            share = tables[leader][:len(p) // B]
            need = blocks_needed(len(p), 1 + fam % 4)
            if pool.available() >= need:
                rid, next_rid = next_rid, next_rid + 1
                pool.attach(rid, share)
                own = pool.alloc(rid, need - len(share))
                tables[rid] = list(share) + own
                prompts[rid] = p
                pool.cow_forks += bool(share)
        elif code == 4:                                     # match peek
            m, ids = pool.match(_prompt(families, fam, length))
            assert m % B == 0 and m == B * len(ids)
        _check(pool, tables)
    # drain: every request releases; refcounts must reach exactly zero
    for rid in sorted(tables):
        pool.release(rid)
    tables.clear()
    _check(pool, tables)
    assert (pool.refs == 0).all()
    # only registry residents remain in use, all evictable now
    assert pool.blocks_in_use() == len(pool._registry)
    assert pool.available() == pool.num_blocks


# ------------------------------------------------------- randomized driver
@pytest.mark.parametrize("seed", range(10))
def test_random_pool_ops_preserve_accounting(seed):
    rng = np.random.default_rng(seed)
    ops = list(zip(rng.integers(0, 5, 250).tolist(),
                   rng.integers(0, 3, 250).tolist(),
                   rng.integers(0, 64, 250).tolist()))
    _run_ops(ops, budget_blocks=4 + seed % 5)


@given(ops=st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2),
                              st.integers(0, 63)), max_size=120))
@settings(max_examples=60, deadline=None)
def test_hypothesis_pool_ops_preserve_accounting(ops):
    _run_ops(ops)


# ----------------------------------------------------------- directed cases
def test_pinned_prefix_blocks_survive_pressure():
    pool = _pool(4)
    p = np.arange(1, 2 * B + 2, dtype=np.int32)     # 2 full blocks + 1
    own = pool.alloc(0, 2)
    pool.publish(0, p, 2 * B)
    pool.release(0)
    assert pool.available() == 4                    # residents are evictable
    # a second request attaches the chain by reference: now pinned
    m, ids = pool.match(p)
    assert m == 2 * B and ids == own
    pool.attach(1, ids)
    # exhaust the pool: only the two non-pinned blocks may be handed out
    got = pool.alloc(2, 2)
    assert set(got).isdisjoint(ids)
    with pytest.raises(RuntimeError):
        pool.alloc(3, 1)
    assert ids == pool.table(1)                     # pinned chain intact
    assert all(b in pool._digest_of for b in ids)   # ... and still published


def test_match_never_covers_last_prompt_token():
    pool = _pool(4)
    p = np.arange(1, 2 * B + 1, dtype=np.int32)     # exactly 2 blocks
    pool.alloc(0, 2)
    pool.publish(0, p, 2 * B)
    m, ids = pool.match(p)
    # the last token must be computed for first-output logits: block 0 only
    assert m == B and len(ids) == 1
    pool.release(0)


def test_cow_share_survives_leader_release():
    pool = _pool(6)
    p = np.arange(1, 2 * B + 4, dtype=np.int32)
    leader = pool.alloc(0, 3)
    share = leader[:2]                              # full prompt blocks
    pool.attach(1, share)
    own = pool.alloc(1, 1)
    assert set(pool.shared) >= set(share)
    pool.release(0)                                 # leader exits first
    assert all(pool.refs[b] == 1 for b in share)    # follower still holds
    assert all(b not in pool._free for b in share)
    pool.release(1)
    # nothing published: every block returns to the free list
    assert (pool.refs == 0).all()
    assert sorted(pool._free) == list(range(pool.num_blocks))
    del p, own


def test_budget_smaller_than_one_block_rejected():
    cfg = _cfg()
    with pytest.raises(ValueError):
        BlockPool(cfg, _block_bytes(cfg) - 1)
    with pytest.raises(ValueError):
        BlockPool(cfg, 0)


def test_eviction_is_lru_deepest_first():
    pool = _pool(4)
    p = np.arange(1, 3 * B + 2, dtype=np.int32)     # 3 full blocks
    chain = pool.alloc(0, 3)
    pool.publish(0, p, 3 * B)
    pool.release(0)
    # allocating past the free list evicts residents; deepest chain blocks
    # were touched LAST-to-first on publish, so the TAIL evicts first
    got = pool.alloc(1, 2)
    assert got[0] not in chain                      # the one free block
    assert got[1] == chain[2]                       # tail evicted before root
    assert pool.evictions == 1
