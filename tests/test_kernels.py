"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import weight_scale
from repro.core.packing import pack_int4, quantize_weight, unpack_int4
from repro.kernels import ops, ref
from repro.kernels.act_quant import act_quant_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas

SHAPES = [(8, 16, 8), (32, 64, 48), (128, 256, 128), (64, 512, 256),
          (256, 128, 384), (16, 1024, 64)]


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05)
    return x, w


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_int8_matmul_sweep(m, k, n):
    x, w = _mk(m, k, n, seed=m + k)
    s_w = weight_scale(w, 8, axis=1)
    w8 = jnp.round(jnp.clip(w / s_w, -127, 127)).astype(jnp.int8)
    s_a = jnp.float32(float(jnp.max(jnp.abs(x))) / 127)
    out = ops.int8_matmul(x, w8, s_a, s_w)
    x8 = ref.act_quant_ref(x, s_a, 8)
    exp = ref.int8_matmul_ref(x8, w8, s_a, s_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_int4_matmul_sweep(m, k, n):
    x, w = _mk(m, k, n, seed=m + n)
    s_w = weight_scale(w, 4, axis=1)
    wp, _ = quantize_weight(w, s_w, 4)
    s_a = jnp.float32(float(jnp.max(jnp.abs(x))) / 8)
    out = ops.int4_matmul(x, wp, s_a, s_w, a_bits=4)
    x4 = ref.act_quant_ref(x, s_a, 4)
    exp = ref.int4_matmul_ref(x4, wp, s_a, s_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m,k", [(8, 16), (64, 128), (256, 96)])
def test_act_quant_sweep(m, k, bits):
    rng = np.random.default_rng(m * k + bits)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 3)
    s = jnp.float32(0.1)
    out = act_quant_pallas(x, s, bits=bits, bm=min(8, m), interpret=True)
    exp = ref.act_quant_ref(x, s, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_out_dtypes(dtype):
    x, w = _mk(64, 128, 64)
    s_w = weight_scale(w, 8, axis=1)
    w8 = jnp.round(jnp.clip(w / s_w, -127, 127)).astype(jnp.int8)
    s_a = jnp.float32(0.05)
    out = int8_matmul_pallas(ref.act_quant_ref(x, s_a, 8), w8, s_a, s_w,
                             out_dtype=dtype, interpret=True)
    assert out.dtype == dtype


def test_block_shape_variants():
    """BlockSpec tilings must not change results."""
    x, w = _mk(128, 256, 128, seed=7)
    s_w = weight_scale(w, 4, axis=1)
    wp, _ = quantize_weight(w, s_w, 4)
    s_a = jnp.float32(0.07)
    x4 = ref.act_quant_ref(x, s_a, 4)
    exp = ref.int4_matmul_ref(x4, wp, s_a, s_w)
    for bm, bn, bk in [(32, 32, 64), (64, 128, 128), (128, 64, 256)]:
        out = int4_matmul_pallas(x4, wp, s_a, s_w.reshape(1, -1), bm=bm,
                                 bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-7, 9, size=(64, 32)).astype(np.int8))
    packed = pack_int4(codes, axis=0)
    assert packed.shape == (32, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed, axis=0)),
                                  np.asarray(codes))
    # stacked (layers/experts) packing along K = axis -2
    codes3 = jnp.asarray(rng.integers(-7, 9, size=(3, 10, 6)).astype(np.int8))
    packed3 = pack_int4(codes3, axis=-2)
    assert packed3.shape == (3, 5, 6)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed3, axis=-2)),
                                  np.asarray(codes3))


def test_int4_hbm_bytes_are_half_of_int8():
    """The deployment asset: packed int4 weights move half the bytes."""
    w = jnp.zeros((512, 256))
    s = jnp.ones((1, 256))
    wp, _ = quantize_weight(w, s, 4)
    w8, _ = quantize_weight(w, s, 8)
    assert wp.size * wp.dtype.itemsize * 2 == w8.size * w8.dtype.itemsize


@pytest.mark.parametrize("shape", [(2, 64, 4, 2, 16, 16, 16, True),
                                   (1, 128, 8, 8, 32, 32, 16, True),
                                   (2, 64, 4, 4, 16, 32, 16, False),
                                   (1, 256, 4, 1, 64, 64, 64, True)])
def test_flash_attention_sweep(shape):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import _repeat_kv, full_attention
    B, S, H, Hkv, dh, bq, bk, causal = shape
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=True)
    ref = full_attention(q, _repeat_kv(k, H // Hkv), _repeat_kv(v, H // Hkv),
                         causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import full_attention
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True, bq=16, bk=16,
                                 interpret=True)
    ref = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
