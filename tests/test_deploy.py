"""Deployment subsystem tests (DESIGN.md §9).

Invariants:
* ``ExecutionPlan.build`` reproduces EVERY legacy
  ``segments_for(cfg, policy, use_pallas, fuse_epilogue)`` combination,
  across families and policies (the shim and the plan can never drift);
* invalid combinations (chunked prefill on token-only families, quantized KV
  without the slot cache, bad backend/dtype names) fail at plan build, not
  mid-serve;
* the plan's decode dtype is THE serving dtype: engine state and slot cache
  allocate with it, for both prefill modes;
* empty prompts are rejected at ``ServingEngine.submit`` for both prefill
  modes (regression: token mode used to crash on ``req.prompt[-1]``);
* deploy → save → load → serve emits token streams byte-identical to serving
  the in-memory DeployedModel, for int8 and int4 weight/KV variants, with no
  fp weights in the artifact and no recalibration on load.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import DeployedModel, ExecutionPlan, deploy
from repro.deploy.plan import plan_from_meta, plan_to_meta
from repro.models import api
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _units(cfg):
    return cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers


# ------------------------------------------------------------ plan resolution

def test_segment_resolution_pinned():
    """Frozen expected segments for representative policies. The shim-vs-plan
    comparison below shares one resolver on both sides, so THIS fixture is
    what catches a resolver regression."""
    from repro.models.layers import QuantSpec
    cfg = reduced(get_config("stablelm-3b"))            # 4 layers
    pol = QuantPolicy(num_layers=4, mode="int", last_k_int4=2)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas")  # fuse auto-on
    kw = dict(mode="int", use_pallas=True, fuse_epilogue=True)
    assert plan.segments == ((0, 2, QuantSpec(w_bits=8, a_bits=8, **kw)),
                             (2, 4, QuantSpec(w_bits=4, a_bits=4, **kw)))
    assert ExecutionPlan.build(cfg, None).segments == ((0, 4, QuantSpec()),)

    xl = reduced(get_config("xlstm-1.3b"))   # 4 layers, slstm_every=2 -> 2 groups
    xplan = ExecutionPlan.build(xl, QuantPolicy(num_layers=4, mode="int",
                                                last_k_int4=2),
                                backend="pallas")
    assert xplan.segments == (
        (0, 1, QuantSpec(mode="int", w_bits=8, a_bits=8, use_pallas=True)),
        (1, 2, QuantSpec(mode="int", w_bits=4, a_bits=4, use_pallas=True)))


@pytest.mark.parametrize("arch", ["stablelm-3b", "xlstm-1.3b", "zamba2-2.7b",
                                  "seamless-m4t-medium", "tinybert4"])
def test_plan_reproduces_legacy_segments(arch):
    """The legacy segments_for shim and the plan resolve identically for
    every (policy, use_pallas, fuse_epilogue) combination — i.e. build()'s
    backend/fuse mapping matches the legacy booleans across families. (Both
    sides share the resolver; test_segment_resolution_pinned pins its
    actual output.)"""
    cfg = reduced(get_config(arch))
    n = _units(cfg)
    policies = [None,
                QuantPolicy(num_layers=n, mode="int", last_k_int4=0),
                QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2),
                QuantPolicy(num_layers=n, mode="fake", last_k_int4=n)]
    for pol, up, fe in itertools.product(policies, (False, True),
                                         (False, True)):
        legacy = api.segments_for(cfg, pol, use_pallas=up, fuse_epilogue=fe)
        plan = ExecutionPlan.build(cfg, pol,
                                   backend="pallas" if up else "reference",
                                   fuse_epilogue=fe)
        assert plan.segments == tuple(legacy), (arch, pol, up, fe)


def test_plan_auto_resolution():
    dense = reduced(get_config("stablelm-3b"))
    plan = ExecutionPlan.build(dense, None, backend="pallas")
    assert plan.prefill_mode == "chunked"
    assert plan.fuse_epilogue          # pallas backend fuses by default
    assert plan.kv_bits == 16          # follows cfg.kv_bits
    assert ExecutionPlan.build(dense.replace(kv_bits=8), None).kv_bits == 8

    xl = reduced(get_config("xlstm-1.3b"))
    assert ExecutionPlan.build(xl, None).prefill_mode == "token"
    ref = ExecutionPlan.build(dense, None)
    assert not ref.fuse_epilogue and not ref.use_pallas


def test_plan_validation_fails_at_build():
    dense = reduced(get_config("stablelm-3b"))
    xl = reduced(get_config("xlstm-1.3b"))
    with pytest.raises(ValueError, match="backend"):
        ExecutionPlan.build(dense, None, backend="cuda")
    with pytest.raises(ValueError, match="decode_dtype"):
        ExecutionPlan.build(dense, None, decode_dtype="float16")
    with pytest.raises(ValueError, match="kv_bits"):
        ExecutionPlan.build(dense, None, kv_bits=2)
    with pytest.raises(ValueError, match="slot cache"):
        ExecutionPlan.build(dense, None, prefill_mode="token", kv_bits=4)
    with pytest.raises(ValueError, match="prefill_mode"):
        ExecutionPlan.build(xl, None, prefill_mode="chunked")
    with pytest.raises(ValueError, match="transformer-family"):
        ExecutionPlan.build(xl, None, kv_bits=8)
    with pytest.raises(ValueError, match="decoder layers"):
        ExecutionPlan.build(reduced(get_config("seamless-m4t-medium")),
                            QuantPolicy(num_layers=7, mode="int"))


def test_plan_meta_round_trip():
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      int4_layers=(1, 3), grad_mode="ste")
    plan = ExecutionPlan.build(cfg, pol, backend="pallas", kv_bits=4,
                               decode_dtype="bfloat16")
    plan2 = plan_from_meta(plan_to_meta(plan))
    assert plan2 == plan


def test_plan_meta_ignores_unknown_fields():
    """Forward compat: a newer build may add cfg/policy fields without
    bumping the artifact version; older readers must drop them, not crash."""
    cfg = reduced(get_config("stablelm-3b"))
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int", last_k_int4=2)
    meta = plan_to_meta(ExecutionPlan.build(cfg, pol))
    meta["cfg"]["some_future_knob"] = 7
    meta["policy"]["another_future_knob"] = "x"
    assert plan_from_meta(meta) == ExecutionPlan.build(cfg, pol)


# ----------------------------------------------------------- engine coupling

def _int_model(cfg, *, kv_bits=16, backend="reference"):
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    plan = ExecutionPlan.build(cfg, pol, backend=backend, kv_bits=kv_bits)
    return deploy(api.init_model(cfg, KEY), plan)


def test_engine_uses_plan_decode_dtype():
    """One dtype end-to-end: the plan's decode_dtype is what the slot cache
    (chunked) and the decode state (token mode) actually allocate."""
    cfg = reduced(get_config("stablelm-3b"))
    for dt_name, dt in (("float32", jnp.float32), ("bfloat16", jnp.bfloat16)):
        n = cfg.num_layers
        pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
        plan = ExecutionPlan.build(cfg, pol, decode_dtype=dt_name)
        model = deploy(api.init_model(cfg, KEY), plan)
        eng = ServingEngine(model, slots=1, max_len=32)
        assert eng.dtype == dt
        assert eng.kv.state["k"].dtype == dt

        tok_plan = ExecutionPlan.build(cfg, pol, prefill_mode="token",
                                       decode_dtype=dt_name)
        tok_eng = ServingEngine(model.params, tok_plan, slots=1, max_len=32)
        assert tok_eng.state["k"].dtype == dt


def test_engine_requires_plan_for_raw_params():
    cfg = reduced(get_config("stablelm-3b"))
    with pytest.raises(TypeError, match="ExecutionPlan"):
        ServingEngine(api.init_model(cfg, KEY), slots=1, max_len=32)


@pytest.mark.parametrize("prefill_mode", ["chunked", "token"])
def test_empty_prompt_rejected_at_submit(prefill_mode):
    """Regression: token mode read ``req.prompt[-1]`` with no guard — an
    empty prompt crashed mid-step instead of failing at submit."""
    cfg = reduced(get_config("stablelm-3b"))
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    plan = ExecutionPlan.build(cfg, pol, prefill_mode=prefill_mode)
    model = deploy(api.init_model(cfg, KEY), plan)
    eng = ServingEngine(model, slots=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(prompt=np.array([], np.int32), max_new_tokens=2))


def test_token_mode_oversized_request_rejected():
    """Token mode writes through a shared clamping cursor — past max_len the
    last cache row is silently overwritten; reject at submit like chunked."""
    cfg = reduced(get_config("stablelm-3b"))
    plan = ExecutionPlan.build(cfg, None, prefill_mode="token")
    eng = ServingEngine(api.init_model(cfg, KEY), plan, slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=np.arange(1, 11, dtype=np.int32),
                           max_new_tokens=12))


# ------------------------------------------------------- artifact round trip

def _streams(model_or_params, plan=None, *, prompts, max_new=4):
    eng = (ServingEngine(model_or_params, plan, slots=2, max_len=64)
           if plan is not None else
           ServingEngine(model_or_params, slots=2, max_len=64))
    for p in prompts:
        eng.submit(Request(prompt=p.copy(), max_new_tokens=max_new))
    eng.run_until_drained()
    return {r.rid: r.out.tolist() for r in eng.done}


@pytest.mark.parametrize("weights,kv_bits", [("int8", 8), ("int4", 4),
                                             ("int4", 16)])
def test_artifact_serve_matches_in_memory(tmp_path, weights, kv_bits):
    """deploy → save → load → serve must emit token streams byte-identical
    to serving the in-memory DeployedModel, with no fp weights in the
    artifact (nothing to recalibrate from)."""
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode="int",
                      last_k_int4=n if weights == "int4" else 0)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas", kv_bits=kv_bits)
    model = deploy(api.init_model(cfg, KEY), plan)

    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8], np.int32)]
    mem = _streams(model, prompts=prompts)

    loaded = DeployedModel.load(model.save(str(tmp_path / "artifact")))
    assert loaded.plan == plan
    # an equal-but-distinct plan passed alongside the model is accepted
    ServingEngine(loaded, ExecutionPlan.build(cfg, pol, backend="pallas",
                                              kv_bits=kv_bits),
                  slots=1, max_len=64)
    leaf_paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
                  for path, _ in
                  jax.tree_util.tree_flatten_with_path(loaded.params)[0]]
    assert not any(p == "w" or p.endswith("/w") for p in leaf_paths), \
        "artifact must hold packed codes only, no fp weights"

    art = _streams(loaded, prompts=prompts)
    assert art == mem


def test_artifact_overwrite_and_file_collision(tmp_path):
    """Re-exporting over an existing artifact publishes the new payload,
    cleans up the backup, and never rmtree's the old artifact before the
    new one lands; a plain file at the target is a clear error."""
    from repro.checkpoint.manager import load_artifact, save_artifact
    p = str(tmp_path / "a")
    save_artifact(p, {"x": np.zeros(2)}, {"format": "t", "version": 1})
    save_artifact(p, {"x": np.ones(3)}, {"format": "t", "version": 1})
    tree, _ = load_artifact(p)
    np.testing.assert_array_equal(tree["x"], np.ones(3))
    leftovers = [d.name for d in tmp_path.iterdir()
                 if d.name.startswith((".old_artifact_", ".tmp_artifact_"))]
    assert not leftovers
    plain = tmp_path / "plain"
    plain.write_text("x")
    with pytest.raises(ValueError, match="not an artifact directory"):
        save_artifact(str(plain), {"x": np.zeros(1)}, {})


def test_artifact_rejects_foreign_payload(tmp_path):
    from repro.checkpoint.manager import save_artifact
    path = save_artifact(str(tmp_path / "x"), {"a": np.zeros(2)},
                         {"format": "something-else", "version": 1})
    with pytest.raises(ValueError, match="artifact"):
        DeployedModel.load(path)


def test_serve_cli_artifact_round_trip(tmp_path, capsys):
    """Acceptance: `python -m repro.launch.serve --artifact <path>` serves a
    previously exported model without fp weights or recalibration, with the
    same token accounting as the exporting run."""
    from repro.launch import serve
    art = str(tmp_path / "artifact")
    base = ["--reduced", "--requests", "2", "--slots", "1", "--max-len", "64"]
    serve.main(base + ["--export", art])
    exported = capsys.readouterr().out
    serve.main(["--artifact", art, "--requests", "2", "--slots", "1",
                "--max-len", "64"])
    served = capsys.readouterr().out
    line = [ln for ln in exported.splitlines() if "requests," in ln]
    line2 = [ln for ln in served.splitlines() if "requests," in ln]
    # same request burst, same tokens-per-request accounting
    assert line and line2
    assert line[0].split("(")[0].split(",")[:3] == \
        line2[0].split("(")[0].split(",")[:3]
