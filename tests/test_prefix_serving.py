"""Prefix-reuse + batched-prefill subsystem and serving-lifecycle fixes
(DESIGN.md §11).

Tentpole invariants:
* a prefix-cache HIT stream is byte-identical to the cold-cache stream for
  the same (prompt, seed) — pinned for int8 and int4 weight plans across
  kv_bits 16/8/4 (block-chunked prefill makes hit and cold runs attend
  bit-equal rows by construction);
* batched bucketed prefill emits token-for-token the same streams as the
  serial batch-1 schedule;
* the PrefixCache refcounts pinned blocks (never evicted mid-flight) and
  LRU-evicts under byte-budget pressure; hash collisions are verified away
  by token comparison.

Lifecycle regressions:
* cancel() truncates ``req.out`` to ``max_new_tokens`` exactly like every
  other exit (one finalize helper);
* token-mode engines gate admission on the LIVE shared cursor and reset
  state when idle instead of silently clamping KV writes past max_len;
* deadline-expired queued requests are shed during submit() overflow checks,
  not just at admit — dead entries cannot hold queue_depth against live
  traffic;
* ServeMetrics is bounded (window + pop_summary drain).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.deploy.plan import plan_from_meta, plan_to_meta
from repro.models import api
from repro.serving import (GenerationRequest, PrefixCache, QueueFullError,
                           SamplingParams, Scheduler, ServeMetrics,
                           ServingEngine)
from repro.serving.prefix_cache import PREFIX_BLOCK

KEY = jax.random.PRNGKey(0)


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


_PARAMS_CACHE: dict = {}


def _deployed(cfg, last_k_int4):
    """fp init + int deployment, cached per policy (deterministic)."""
    key = (cfg.name, last_k_int4)
    if key not in _PARAMS_CACHE:
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=last_k_int4)
        plan = ExecutionPlan.build(cfg, pol, backend="pallas")
        _PARAMS_CACHE[key] = (deploy(api.init_model(cfg, KEY), plan).params,
                              pol)
    return _PARAMS_CACHE[key]


def _engine(cfg, *, last_k_int4, kv_bits, prefix_cache=0, prefill_batch=1,
            slots=2, max_len=64):
    params, pol = _deployed(cfg, last_k_int4)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas", kv_bits=kv_bits,
                               prefix_cache=prefix_cache,
                               prefill_batch=prefill_batch)
    return ServingEngine(params, plan, slots=slots, max_len=max_len)


def _serve_one(eng, prompt, max_new=5, sampling=None):
    eng.submit(GenerationRequest(prompt=prompt.copy(), max_new_tokens=max_new,
                                 sampling=sampling))
    eng.run_until_drained()
    return eng.pop_done()[-1].out.tolist()


# ------------------------------------------------------- prefix-hit equality

@pytest.mark.parametrize("last_k_int4,kv_bits", [
    (0, 16), (0, 8), (0, 4),      # int8 weight plan x kv precisions
    (4, 16), (4, 8), (4, 4),      # int4 weight plan x kv precisions
])
def test_prefix_hit_streams_byte_identical(last_k_int4, kv_bits):
    """Hit streams == cold streams per (prompt, seed): the cached quantized
    rows a hit restores are bit-equal to the rows a cold run computes."""
    cfg = _cfg()
    rng = np.random.default_rng(11)
    prefix = rng.integers(1, cfg.vocab_size, 2 * PREFIX_BLOCK).astype(np.int32)
    tails = [rng.integers(1, cfg.vocab_size, k).astype(np.int32)
             for k in (3, 6, 1)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    sampling = SamplingParams(temperature=0.7, top_k=12, seed=9)

    cold = []
    for p in prompts:
        eng = _engine(cfg, last_k_int4=last_k_int4, kv_bits=kv_bits,
                      prefix_cache=1 << 20)
        cold.append(_serve_one(eng, p, sampling=sampling))

    warm_eng = _engine(cfg, last_k_int4=last_k_int4, kv_bits=kv_bits,
                       prefix_cache=1 << 20)
    warm = [_serve_one(warm_eng, p, sampling=sampling) for p in prompts]

    assert warm == cold
    s = warm_eng.metrics.summary()
    assert s["prefix_hit_rate"] == pytest.approx(2 / 3)   # all but the first
    assert s["prefill_tokens_saved"] == 2 * 2 * PREFIX_BLOCK


def test_prefix_reuse_cuts_prefill_tokens_by_half():
    """The acceptance headline: on a repeated-prefix burst, a warm cache
    computes <= 50% of the prefill tokens the cold path computes."""
    cfg = _cfg()
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(1, cfg.vocab_size, k)
                               .astype(np.int32)])
               for k in (4, 6, 5, 7, 3, 6)]

    def burst(prefix_cache):
        eng = _engine(cfg, last_k_int4=4, kv_bits=4,
                      prefix_cache=prefix_cache, prefill_batch=4, slots=2)
        outs = []
        for p in prompts:                       # warm-up request included
            outs.append(_serve_one(eng, p, max_new=3))
        return outs, eng.metrics.summary()["prefill_tokens"]

    outs_off, tokens_off = burst(0)
    outs_on, tokens_on = burst(1 << 20)
    assert outs_on == outs_off                  # streams unchanged
    # first request computes its full prompt; the other five compute only
    # their suffix (prefix is 2 blocks = 16 of each ~20-token prompt)
    assert tokens_on <= tokens_off // 2, (tokens_on, tokens_off)


def test_chunked_prefill_survives_non_block_aligned_max_len():
    """A bucket capped at a max_len off the 8-token block grid used to make
    the last chunk's scatter clamp its start index and silently overwrite
    real prompt KV rows with padding. The scratch cache now rounds up to
    the block grid: at kv16 the chunked path's rows must be bit-equal to
    the single-forward (prefix off) rows for the same prompt."""
    cfg = _cfg()
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, 57).astype(np.int32)

    def rows_and_out(max_len, prefix_cache):
        eng = _engine(cfg, last_k_int4=0, kv_bits=16, slots=1,
                      max_len=max_len, prefix_cache=prefix_cache)
        out = _serve_one(eng, prompt, max_new=3)
        return np.asarray(eng.kv.state["k"])[:, 0, :len(prompt)], out

    ref_rows, ref_out = rows_and_out(64, 0)           # one fp forward
    rows, out = rows_and_out(60, 1 << 20)             # chunked, capped bucket
    np.testing.assert_array_equal(rows, ref_rows)
    assert out == ref_out


# -------------------------------------------------- batched bucketed prefill

def test_batched_prefill_matches_serial_token_for_token():
    cfg = _cfg()
    rng = np.random.default_rng(5)
    # mixed buckets (8 and 16) and a sampled request to cover the sampler
    prompts = [rng.integers(1, cfg.vocab_size, k).astype(np.int32)
               for k in (4, 7, 11, 6, 9, 13)]
    streams = {}
    for pb in (1, 4):
        eng = _engine(cfg, last_k_int4=4, kv_bits=8, prefill_batch=pb,
                      slots=4)
        for i, p in enumerate(prompts):
            sampling = SamplingParams(temperature=0.8, seed=i) if i % 2 \
                else None
            eng.submit(GenerationRequest(prompt=p.copy(), max_new_tokens=4,
                                         sampling=sampling))
        eng.run_until_drained()
        streams[pb] = {r.rid: r.out.tolist() for r in eng.pop_done()}
    assert streams[1] == streams[4]


def test_batched_prefill_with_prefix_cache_matches_serial():
    cfg = _cfg()
    rng = np.random.default_rng(6)
    prefix = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(1, cfg.vocab_size, k)
                               .astype(np.int32)]) for k in (3, 5, 2, 6)]
    streams = {}
    for pb in (1, 4):
        eng = _engine(cfg, last_k_int4=4, kv_bits=4, prefix_cache=1 << 20,
                      prefill_batch=pb, slots=4)
        for p in prompts:
            eng.submit(GenerationRequest(prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained()
        streams[pb] = {r.rid: r.out.tolist() for r in eng.pop_done()}
    assert streams[1] == streams[4]


# ------------------------------------------------------- PrefixCache internals

def _fake_rows(n_tokens, fill):
    return {"k_q": np.full((2, n_tokens, 2, 4), fill, np.int8),
            "v_q": np.full((2, n_tokens, 2, 4), fill, np.int8),
            "k_scale": np.full((2, n_tokens, 2), 1.0, np.float32),
            "v_scale": np.full((2, n_tokens, 2), 1.0, np.float32)}


def _block_bytes():
    rows = _fake_rows(PREFIX_BLOCK, 0)
    return sum(a.nbytes for a in rows.values()) + PREFIX_BLOCK * 4


def test_prefix_cache_match_and_gather_roundtrip():
    pc = PrefixCache(budget_bytes=1 << 20)
    prompt = np.arange(1, 2 * PREFIX_BLOCK + 3, dtype=np.int32)
    pc.insert(prompt, 2 * PREFIX_BLOCK,
              lambda lo, hi: _fake_rows(hi - lo, lo))
    # full prompt: both blocks usable (cap is len-1 = 2B+2)
    m, keys = pc.match(prompt)
    assert m == 2 * PREFIX_BLOCK and len(keys) == 2
    rows = pc.gather(keys)
    assert rows["k_q"].shape[1] == 2 * PREFIX_BLOCK
    np.testing.assert_array_equal(rows["k_q"][:, :PREFIX_BLOCK],
                                  _fake_rows(PREFIX_BLOCK, 0)["k_q"])
    np.testing.assert_array_equal(rows["k_q"][:, PREFIX_BLOCK:],
                                  _fake_rows(PREFIX_BLOCK, PREFIX_BLOCK)["k_q"])
    pc.release(keys)
    # a prompt of exactly 2B tokens may only reuse one block: the last
    # token's logits must be computed
    m, keys = pc.match(prompt[:2 * PREFIX_BLOCK])
    assert m == PREFIX_BLOCK and len(keys) == 1
    pc.release(keys)
    # diverging block 2 stops the walk after block 1
    other = prompt.copy()
    other[PREFIX_BLOCK] += 1
    m, keys = pc.match(other)
    assert m == PREFIX_BLOCK
    pc.release(keys)


def test_prefix_cache_refcount_blocks_eviction():
    pc = PrefixCache(budget_bytes=2 * _block_bytes())   # room for 2 blocks
    p1 = np.arange(1, PREFIX_BLOCK + 2, dtype=np.int32)
    p2 = np.arange(100, 100 + PREFIX_BLOCK + 1, dtype=np.int32)
    p3 = np.arange(200, 200 + PREFIX_BLOCK + 1, dtype=np.int32)
    pc.insert(p1, PREFIX_BLOCK, lambda lo, hi: _fake_rows(hi - lo, 1))
    m, pinned = pc.match(p1)
    assert m == PREFIX_BLOCK
    pc.insert(p2, PREFIX_BLOCK, lambda lo, hi: _fake_rows(hi - lo, 2))
    # inserting a third block exceeds the budget: p2's block (LRU, unpinned)
    # must evict while p1's pinned block survives
    pc.insert(p3, PREFIX_BLOCK, lambda lo, hi: _fake_rows(hi - lo, 3))
    assert pc.evictions == 1
    m, k = pc.match(p1)                             # pinned: still cached
    assert m == PREFIX_BLOCK
    pc.release(k)                                   # (drop the extra pin)
    assert pc.match(p2)[0] == 0                     # evicted
    pc.release(pinned)
    # p1 is now unpinned but was TOUCHED by the match above, so LRU order is
    # (p3, p1): the next over-budget insert evicts p3, not p1
    p4 = np.arange(300, 300 + PREFIX_BLOCK + 1, dtype=np.int32)
    pc.insert(p4, PREFIX_BLOCK, lambda lo, hi: _fake_rows(hi - lo, 4))
    assert pc.match(p3)[0] == 0
    m, k = pc.match(p1)
    assert m == PREFIX_BLOCK
    pc.release(k)
    assert pc.bytes <= pc.budget


def test_prefix_cache_hash_collision_rejected(monkeypatch):
    from repro.serving import prefix_cache as mod
    monkeypatch.setattr(mod, "rolling_hash", lambda h, toks: 42)
    pc = PrefixCache(budget_bytes=1 << 20)
    p1 = np.arange(1, PREFIX_BLOCK + 2, dtype=np.int32)
    p2 = np.arange(50, 50 + PREFIX_BLOCK + 1, dtype=np.int32)
    pc.insert(p1, PREFIX_BLOCK, lambda lo, hi: _fake_rows(hi - lo, 1))
    # same hash, different tokens: match must verify and miss
    assert pc.match(p2)[0] == 0


def test_prefix_cache_rejects_bad_budget():
    with pytest.raises(ValueError, match="budget"):
        PrefixCache(budget_bytes=0)


# --------------------------------------------------------- plan / artifact

def test_plan_prefix_knobs_roundtrip_and_default_off():
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int", last_k_int4=2)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas", kv_bits=4,
                               prefix_cache=1 << 22, prefill_batch=8)
    again = plan_from_meta(plan_to_meta(plan))
    assert again.prefix_cache == 1 << 22 and again.prefill_batch == 8
    assert again == plan
    # artifacts written before the knobs existed carry no keys: both off
    meta = plan_to_meta(plan)
    meta["build"].pop("prefix_cache")
    meta["build"].pop("prefill_batch")
    old = plan_from_meta(meta)
    assert old.prefix_cache == 0 and old.prefill_batch == 1


def test_plan_validates_prefix_knobs():
    cfg = _cfg()
    with pytest.raises(ValueError, match="prefill_batch"):
        ExecutionPlan.build(cfg, None, prefill_batch=0)
    with pytest.raises(ValueError, match="prefix_cache"):
        ExecutionPlan.build(cfg, None, prefix_cache=-1)
    with pytest.raises(ValueError, match="chunked"):
        ExecutionPlan.build(cfg, None, prefill_mode="token",
                            prefix_cache=1 << 20)
    bert = dataclasses.replace(cfg, learned_pos=True)
    with pytest.raises(ValueError, match="learned-pos"):
        ExecutionPlan.build(bert, None, prefix_cache=1 << 20)


# --------------------------------------------------- lifecycle bug regressions

def test_cancel_truncates_out_to_max_new_tokens():
    """cancel() funnels through the same finalize helper as length/stop
    exits: req.out can never exceed the request's own max_new_tokens."""
    cfg = _cfg()
    eng = _engine(cfg, last_k_int4=0, kv_bits=16, slots=1)
    req = GenerationRequest(prompt=np.array([3, 1, 4], np.int32),
                            max_new_tokens=4)
    eng.submit(req)
    eng.engine_step()              # prefill + one decode: 2 tokens so far
    # regression scenario: the slot tally outgrew the limit (historically
    # possible via callback re-entrancy); cancel used to ship it untruncated
    slot = next(s for s, r in enumerate(eng.scheduler.active) if r is req)
    eng.generated[slot] = eng.generated[slot] + [7, 8, 9]
    assert eng.cancel(req.rid)
    assert req.finish_reason == "cancelled"
    assert len(req.out) == req.max_new_tokens


def test_cancel_mid_decode_still_reports_generated_prefix():
    cfg = _cfg()
    eng = _engine(cfg, last_k_int4=0, kv_bits=16, slots=1)
    req = GenerationRequest(prompt=np.array([3, 1, 4], np.int32),
                            max_new_tokens=8)
    eng.submit(req)
    eng.engine_step()
    eng.engine_step()
    assert eng.cancel(req.rid)
    assert req.out.tolist() and len(req.out) <= 8
    eng.run_until_drained()                 # engine is still healthy


def test_submit_sheds_expired_queue_entries_when_full():
    """A dead (deadline-expired) queued request must not hold queue_depth
    against live traffic: submit() sheds it instead of raising."""
    t = [0.0]
    sch = Scheduler(slots=1, max_queue=1, clock=lambda: t[0])
    occupant = sch.submit(GenerationRequest(prompt=np.array([1], np.int32)))
    sch.admit()                             # slot busy; queue empty
    assert occupant in sch.active
    dead = sch.submit(GenerationRequest(prompt=np.array([2], np.int32),
                                        deadline_s=0.5))
    t[0] = 1.0                              # deadline passes; slot still busy
    live = sch.submit(GenerationRequest(prompt=np.array([3], np.int32)))
    assert live in [r for _, _, r in sch._heap]
    assert sch.pop_shed() == [dead]
    # still-live entries are NOT shed: the queue really is full now
    with pytest.raises(QueueFullError):
        sch.submit(GenerationRequest(prompt=np.array([4], np.int32)))


def test_engine_finalizes_submit_time_shed():
    cfg = _cfg()
    eng = _engine(cfg, last_k_int4=0, kv_bits=16, slots=1, max_len=32)
    t = [0.0]
    eng.scheduler._clock = lambda: t[0]
    first = GenerationRequest(prompt=np.array([5, 2], np.int32),
                              max_new_tokens=6)
    eng.submit(first)
    eng.engine_step()                       # occupies the only slot
    eng.scheduler.max_queue = 1
    dead = eng.submit(GenerationRequest(prompt=np.array([9], np.int32),
                                        max_new_tokens=2, deadline_s=0.1))
    t[0] = 5.0
    live_stream = eng.submit(GenerationRequest(
        prompt=np.array([7, 7], np.int32), max_new_tokens=2))
    eng.run_until_drained()
    by_rid = {r.rid: r for r in eng.pop_done()}
    assert by_rid[dead.rid].finish_reason == "shed"
    assert len(by_rid[dead.rid].out) == 0
    assert by_rid[live_stream.rid].finish_reason == "length"


def test_submit_time_shed_is_never_orphaned():
    """Entries shed during submit() overflow checks still count as work:
    even if the queue then empties (queued-cancel), the next pump finalizes
    them instead of stranding a stream with no finish_reason."""
    cfg = _cfg()
    eng = _engine(cfg, last_k_int4=0, kv_bits=16, slots=1, max_len=32)
    t = [0.0]
    eng.scheduler._clock = lambda: t[0]
    occupant = GenerationRequest(prompt=np.array([5], np.int32),
                                 max_new_tokens=8)
    eng.submit(occupant)
    eng.engine_step()                       # slot busy
    eng.scheduler.max_queue = 1
    dead_stream = eng.submit(GenerationRequest(
        prompt=np.array([9], np.int32), max_new_tokens=2, deadline_s=0.1))
    t[0] = 5.0
    r2 = eng.submit(GenerationRequest(prompt=np.array([7], np.int32),
                                      max_new_tokens=2))   # sheds the dead one
    assert eng.cancel(r2.rid)               # queue empties again
    eng.cancel(occupant.rid)                # no active work left either
    assert eng.scheduler.has_work           # the shed entry still counts
    eng.run_until_drained()
    assert dead_stream.request.finish_reason == "shed"
    assert dead_stream.finished


def test_token_mode_cursor_resets_instead_of_overflowing():
    """Steady-state token mode: the shared cursor spans slot refills, so an
    engine serving request after request used to walk it past max_len and
    clamp KV writes silently. Admission now gates on the live cursor and an
    idle engine resets — the request served after exhaustion matches a
    fresh engine exactly."""
    cfg = _cfg()
    params, pol = _deployed(cfg, 0)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas",
                               prefill_mode="token")
    prompt = np.array([4, 9, 2, 6], np.int32)

    fresh = ServingEngine(params, plan, slots=1, max_len=16)
    fresh_out = _serve_one(fresh, prompt, max_new=4)

    eng = ServingEngine(params, plan, slots=1, max_len=16)
    outs = [_serve_one(eng, prompt, max_new=4) for _ in range(4)]
    assert eng._cursor <= eng.max_len
    assert outs[0] == fresh_out
    # requests 2+ ran after at least one cursor reset (2 fit per 16-token
    # window); every post-reset request reproduces the fresh-engine stream
    assert outs[2] == fresh_out and outs[3] == fresh_out


def test_token_mode_interleaved_submissions_drain():
    cfg = _cfg()
    params, pol = _deployed(cfg, 0)
    plan = ExecutionPlan.build(cfg, pol, backend="pallas",
                               prefill_mode="token")
    eng = ServingEngine(params, plan, slots=2, max_len=16)
    for k in (3, 4, 2, 5, 3):
        eng.submit(GenerationRequest(
            prompt=np.arange(1, k + 1, dtype=np.int32), max_new_tokens=3))
    eng.run_until_drained()
    done = eng.pop_done()
    assert len(done) == 5
    assert all(r.finish_reason == "length" and len(r.out) == 3 for r in done)


# ----------------------------------------------------------------- metrics

def test_metrics_window_bounds_memory():
    m = ServeMetrics(window=4)
    for i in range(100):
        m.record("decode", 0.001, 1)
        m.record_wait("ttft", 0.002)
    assert len(m._events) == 4 and len(m._waits) == 4
    assert m.summary()["decode_steps"] == 4


def test_metrics_pop_summary_drains():
    m = ServeMetrics()
    m.record("decode", 0.001, 3)
    m.record_prefix(8, 12)
    s = m.pop_summary()
    assert s["total_tokens"] == 3
    assert s["prefix_hit_rate"] == 1.0
    assert s["prefill_tokens_saved"] == 8
    s2 = m.pop_summary()
    assert s2["total_tokens"] == 0 and "prefix_hit_rate" not in s2
