"""ReplicaSet: data-parallel engines behind one admission surface
(DESIGN.md §16). Single-device — replicas share the same host arrays."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.serving import (GenerationRequest, ReplicaSet, SamplingParams,
                           ServingEngine)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend="reference", kv_bits=8)
    return deploy(api.init_model(cfg, jax.random.PRNGKey(0)), plan)


def _prompts(vocab, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, int(rng.integers(3, 7))).astype(np.int32)
            for _ in range(n)]


def test_shared_rid_space(model):
    rs = ReplicaSet(model, replicas=3, slots=2, max_len=32)
    streams = [rs.submit(GenerationRequest(prompt=p, max_new_tokens=2))
               for p in _prompts(model.plan.cfg.vocab_size, 6)]
    # one counter set-wide: sequential rids even though members alternate
    assert [s.rid for s in streams] == list(range(6))
    assert all(e.scheduler._ids is rs.engines[0].scheduler._ids
               for e in rs.engines)


def test_least_loaded_dispatch(model):
    rs = ReplicaSet(model, replicas=2, slots=2, max_len=32)
    p1, p2 = _prompts(model.plan.cfg.vocab_size, 2)
    s1 = rs.submit(GenerationRequest(prompt=p1, max_new_tokens=2))
    s2 = rs.submit(GenerationRequest(prompt=p2, max_new_tokens=2))
    owner = [next(e for e in rs.engines if s.rid in e._streams)
             for s in (s1, s2)]
    assert owner[0] is rs.engines[0]       # tie -> lowest index
    assert owner[1] is rs.engines[1]       # then the now-emptier member


def test_streams_match_single_engine(model):
    vocab = model.plan.cfg.vocab_size
    prompts = _prompts(vocab, 8, seed=3)

    def run(make):
        eng = make()
        streams = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
                   for p in prompts]
        eng.run_until_drained()
        return [tuple(s.result().tokens) for s in streams]

    single = run(lambda: ServingEngine(model, slots=2, max_len=32))
    multi = run(lambda: ReplicaSet(model, replicas=2, slots=2, max_len=32))
    # tokens are a function of (prompt, seed) only — never of the member,
    # slot or batch that served the request
    assert single == multi


def test_replicas_drain_in_fewer_steps(model):
    vocab = model.plan.cfg.vocab_size
    prompts = _prompts(vocab, 8, seed=5)

    def steps(make):
        eng = make()
        for p in prompts:
            eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
        return eng.run_until_drained()

    one = steps(lambda: ServingEngine(model, slots=2, max_len=32))
    two = steps(lambda: ReplicaSet(model, replicas=2, slots=2, max_len=32))
    # 2x the slots pumped per step: the backlog halves (within a step or
    # two of slack for ragged tail batches)
    assert two <= one // 2 + 2


def test_pop_done_rid_sorted(model):
    rs = ReplicaSet(model, replicas=2, slots=2, max_len=32)
    prompts = _prompts(model.plan.cfg.vocab_size, 6, seed=7)
    for p in prompts:
        rs.submit(GenerationRequest(prompt=p, max_new_tokens=3))
    rs.run_until_drained()
    done = rs.pop_done()
    assert [r.rid for r in done] == sorted(r.rid for r in done)
    assert len(done) == 6
    assert rs.pop_done() == []
    assert rs.done == []


def test_cancel_reaches_any_member(model):
    rs = ReplicaSet(model, replicas=2, slots=1, max_len=32)
    prompts = _prompts(model.plan.cfg.vocab_size, 4, seed=9)
    streams = [rs.submit(GenerationRequest(prompt=p, max_new_tokens=8))
               for p in prompts]
    # rid 3 landed on member 1 (round-robin under equal load); the set-level
    # cancel must find it without a replica argument
    assert rs.cancel(streams[3].rid)
    assert streams[3].cancel() is False    # already cancelled
    rs.run_until_drained()
    by_rid = {r.rid: r for r in rs.pop_done()}
    assert by_rid[streams[3].rid].finish_reason == "cancelled"
    assert all(by_rid[s.rid].finish_reason == "length"
               for s in streams if s is not streams[3])


def test_fanout_children_get_unique_rids(model):
    rs = ReplicaSet(model, replicas=2, slots=2, max_len=32)
    vocab = model.plan.cfg.vocab_size
    p = _prompts(vocab, 1, seed=11)[0]
    kids = rs.submit(GenerationRequest(
        prompt=p, max_new_tokens=2,
        sampling=SamplingParams(temperature=0.8, seed=0, n=3)))
    solo = rs.submit(GenerationRequest(prompt=p, max_new_tokens=2))
    rids = [s.rid for s in kids] + [solo.rid]
    # children draw from their member's scheduler — which is the SHARED
    # counter, so no rid collides across members
    assert len(set(rids)) == len(rids)
    rs.run_until_drained()
    assert len(rs.pop_done()) == 4
