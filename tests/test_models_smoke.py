"""Per-arch smoke tests: reduced config, one forward/train step, decode step.

Covers all 10 assigned architectures + the paper's TinyBERT4 (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, input_specs, reduced, \
    shape_applicable
from repro.configs.archs import ASSIGNED
from repro.core.policy import QuantPolicy
from repro.models import api
from repro.models.transformer import lm_loss

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    if cfg.input_kind == "embeds":
        return {"src_embeds": jax.random.normal(KEY, (B, S, cfg.d_model)),
                "tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.input_kind == "tokens+patches":
        return {"tokens": jnp.ones((B, S), jnp.int32),
                "patch_embeds": jax.random.normal(
                    KEY, (B, cfg.num_patches, cfg.d_model)),
                "patch_mask": jnp.zeros((B, S), bool).at[:, :4].set(True)}
    return {"tokens": jnp.ones((B, S), jnp.int32)}


def _policy(cfg, mode="fake"):
    n = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    return QuantPolicy(num_layers=n, mode=mode, last_k_int4=n // 2)


@pytest.mark.parametrize("arch", ASSIGNED + ["tinybert4"])
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = api.init_model(cfg, KEY)
    segs = api.segments_for(cfg, _policy(cfg))
    B, S = 2, 16
    logits, _, _, aux = api.forward(params, cfg, segs, **_inputs(cfg, B, S))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_decreases_loss(arch):
    """A few SGD steps on the QAT fake-quant loss must reduce it."""
    cfg = reduced(get_config(arch))
    params = api.init_model(cfg, KEY)
    segs = api.segments_for(cfg, _policy(cfg))
    B, S = 2, 16
    inputs = _inputs(cfg, B, S)
    labels = jnp.ones((B, S), jnp.int32)

    @jax.jit
    def step(p):
        def loss_fn(pp):
            logits, _, _, aux = api.forward(pp, cfg, segs, **inputs)
            return lm_loss(logits, labels) + aux
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    losses = []
    for _ in range(5):
        params, l = step(params)
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = api.init_model(cfg, KEY)
    segs = api.segments_for(cfg, _policy(cfg))
    B = 2
    state = api.decode_state(cfg, B, 32, dtype=jnp.float32)
    extra = api.decode_extra_inputs(cfg, B, 16, dtype=jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, state, _, _ = api.forward(params, cfg, segs, state=state,
                                      tokens=tok, **extra)
    logits2, state, _, _ = api.forward(params, cfg, segs, state=state,
                                       tokens=tok, **extra)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_full_forward():
    """Incremental decode == full causal forward (dense GQA arch)."""
    cfg = reduced(get_config("internlm2-20b"))
    params = api.init_model(cfg, KEY)
    segs = api.segments_for(cfg, None)
    T = 8
    toks = jax.random.randint(KEY, (2, T), 0, cfg.vocab_size)
    full, *_ = api.forward(params, cfg, segs, tokens=toks)
    state = api.decode_state(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, state, _, _ = api.forward(params, cfg, segs, state=state,
                                      tokens=toks[:, t:t + 1])
        outs.append(lg)
    inc = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               atol=5e-4, rtol=1e-3)


def test_shape_applicability_matrix():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    runs = {}
    for arch in ASSIGNED:
        cfg = get_config(arch)
        ok, _ = shape_applicable(cfg, SHAPES["long_500k"])
        runs[arch] = ok
    assert runs["xlstm-1.3b"] and runs["zamba2-2.7b"]
    assert sum(runs.values()) == 2
    for arch in ASSIGNED:  # all other shapes apply to every arch
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(arch), SHAPES[s])[0]


def test_input_specs_cover_every_cell():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            if not shape_applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)


def test_moe_sorted_matches_dense():
    """Sort-based MoE dispatch == dense one-hot dispatch (no-overflow regime);
    the sorted path exists to kill the dispatch-einsum FLOPs (SS Perf)."""
    from repro.models.layers import QuantSpec
    from repro.models.transformer import init_moe, moe_apply, moe_apply_sorted
    cfg = reduced(get_config("qwen2-moe-a2.7b")).replace(
        capacity_factor=8.0, moe_group_size=9999)
    p = init_moe(jax.random.PRNGKey(0), cfg, stacked=None)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense, aux_d = moe_apply(x, p, cfg, QuantSpec())
    y_sorted, aux_s = moe_apply_sorted(x, p, cfg, QuantSpec())
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_dense),
                               atol=2e-5)
    assert float(aux_d) == pytest.approx(float(aux_s))
    # differentiable (scatter-add / gather paths)
    g = jax.grad(lambda pp: float(0) + jax.numpy.sum(
        moe_apply_sorted(x, pp, cfg, QuantSpec())[0] ** 2))(p)
    gn = sum(float(jax.numpy.sum(jax.numpy.abs(l)))
             for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
