"""Serving engine + fault-tolerant training loop behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainHParams, get_config, reduced
from repro.core.policy import QuantPolicy
from repro.data import lm_batches
from repro.deploy import ExecutionPlan, deploy
from repro.launch.serve import Request, ServingEngine
from repro.launch.train import run_training
from repro.models import api

KEY = jax.random.PRNGKey(0)


def _engine(slots=2, arch="stablelm-3b"):
    cfg = reduced(get_config(arch))
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    plan = ExecutionPlan.build(cfg, pol)
    model = deploy(api.init_model(cfg, KEY), plan)
    return ServingEngine(model, slots=slots, max_len=64), cfg


def test_engine_drains_batched_requests():
    eng, cfg = _engine(slots=2)
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(Request(prompt=rng.integers(1, cfg.vocab_size, 6)
                           .astype(np.int32), max_new_tokens=4))
    steps = eng.run_until_drained()
    assert len(eng.done) == 5
    assert all(len(r.out) == 4 for r in eng.done)
    assert steps < 100


def test_engine_outputs_deterministic():
    outs = []
    for _ in range(2):
        eng, cfg = _engine(slots=1)
        eng.submit(Request(prompt=np.arange(1, 7, dtype=np.int32),
                           max_new_tokens=5))
        eng.run_until_drained()
        outs.append(eng.done[0].out.tolist())
    assert outs[0] == outs[1]


def test_training_resumes_from_checkpoint(tmp_path):
    cfg = reduced(get_config("stablelm-3b")).replace(num_layers=2)
    pol = QuantPolicy(num_layers=2, mode="fake", last_k_int4=1)
    hp = TrainHParams(total_steps=6, lr_weights=1e-4)
    data = lm_batches(cfg.vocab_size, 16, 4, prefetch=False)

    seen = []
    run_training(cfg, pol, hp, iter(data), ckpt_dir=str(tmp_path),
                 ckpt_every=2, log_every=0, max_steps=4,
                 on_step=lambda s, st, m: seen.append(s))
    assert seen == [0, 1, 2, 3]

    # "crash" after step 4 -> a new run must resume at step 4, not 0
    seen2 = []
    run_training(cfg, pol, hp, iter(data), ckpt_dir=str(tmp_path),
                 ckpt_every=2, log_every=0, max_steps=6,
                 on_step=lambda s, st, m: seen2.append(s))
    assert seen2 == [4, 5]


def test_int8_kv_cache_decode_close():
    """int8 KV cache (SS Perf, decode hillclimb): logits track bf16 cache.

    Random-weight logits are nearly tied, so argmax agreement is a weak
    signal; correlation is the meaningful check here.
    """
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models import api
    cfg = reduced(get_config("internlm2-20b"))
    p = api.init_model(cfg, jax.random.PRNGKey(0))
    segs = api.segments_for(cfg, None)
    T = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0,
                              cfg.vocab_size)
    outs = []
    for dt in (jnp.float32, jnp.int8):
        st = api.decode_state(cfg, 2, 16, dtype=dt)
        lg_all = []
        for t in range(T):
            lg, st, _, _ = api.forward(p, cfg, segs, state=st,
                                       tokens=toks[:, t:t + 1])
            lg_all.append(lg)
        outs.append(np.asarray(jnp.concatenate(lg_all, 1), np.float32))
    corr = np.corrcoef(outs[0].ravel(), outs[1].ravel())[0, 1]
    assert corr > 0.99, corr
