"""Paged KV serving (DESIGN.md §15): block tables, COW forks, bit-identity.

Tentpole invariants:
* a ``kv_paging='paged'`` engine emits per-request token streams
  byte-identical to the dense engine across kv_bits 16/8/4, with the prefix
  registry on or off (prefix HITS attach resident blocks by reference and
  must not perturb a single token);
* ``SamplingParams.n > 1`` fans into n deterministic streams — sample 0
  equals the plain n=1 stream, paged (copy-on-write shared prompt blocks)
  equals dense (plain expansion), samples are seeded apart;
* the pallas decode path gets block-table indirection bit-identical to the
  dense gather (``decode_attention_paged``);
* ONE byte budget drives admission: oversized requests are rejected at
  submit, capacity-bound bursts complete by queueing (never corrupting),
  and the pool's KV gauges surface through ServeMetrics;
* ``kv_paging`` is a plan axis: artifact meta round-trips it and plans
  missing the key (old artifacts) load as dense.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.deploy.plan import plan_from_meta, plan_to_meta
from repro.kernels.decode_attention import (decode_attention_paged,
                                            decode_attention_pallas,
                                            gather_kv_blocks)
from repro.models import api
from repro.serving import GenerationRequest, SamplingParams, ServingEngine
from repro.serving.api import sample_seed
from repro.serving.prefix_cache import PREFIX_BLOCK

KEY = jax.random.PRNGKey(0)


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


_PARAMS_CACHE: dict = {}


def _deployed(cfg):
    if "p" not in _PARAMS_CACHE:
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=cfg.num_layers)
        plan = ExecutionPlan.build(cfg, pol)
        _PARAMS_CACHE["p"] = (deploy(api.init_model(cfg, KEY), plan).params,
                              pol)
    return _PARAMS_CACHE["p"]


def _engine(cfg, *, kv_bits, kv_paging, prefix_cache=0, prefill_batch=1,
            slots=2, max_len=64, backend="reference", **eng_kw):
    params, pol = _deployed(cfg)
    plan = ExecutionPlan.build(cfg, pol, backend=backend, kv_bits=kv_bits,
                               kv_paging=kv_paging,
                               prefix_cache=prefix_cache,
                               prefill_batch=prefill_batch)
    return ServingEngine(params, plan, slots=slots, max_len=max_len,
                         **eng_kw)


def _prompts(cfg, n=3, seed=7):
    rng = np.random.default_rng(seed)
    ps = [rng.integers(1, cfg.vocab_size, ln).tolist()
          for ln in (11, 5, 23)[:n]]
    if n > len(ps):
        ps += [ps[0][:PREFIX_BLOCK]
               + rng.integers(1, cfg.vocab_size, 4).tolist()]
    return ps


def _streams(eng, prompts, max_new=5):
    streams = [eng.submit(GenerationRequest(
        prompt=p, max_new_tokens=max_new,
        sampling=SamplingParams(temperature=0.8, seed=3 + i)))
        for i, p in enumerate(prompts)]
    eng.run_until_drained()
    return [tuple(s.result().tokens) for s in streams]


_DENSE_GOLDEN: dict = {}


def _dense_streams(cfg, kv_bits, prompts):
    key = (kv_bits, tuple(map(tuple, prompts)))
    if key not in _DENSE_GOLDEN:
        eng = _engine(cfg, kv_bits=kv_bits, kv_paging="dense")
        _DENSE_GOLDEN[key] = _streams(eng, prompts)
    return _DENSE_GOLDEN[key]


# ---------------------------------------------------- stream bit-identity
@pytest.mark.parametrize("kv_bits", [16, 8, 4])
@pytest.mark.parametrize("prefix", [0, 1 << 20])
def test_paged_streams_match_dense(kv_bits, prefix):
    cfg = _cfg()
    prompts = _prompts(cfg, n=4)        # includes a shared-prefix prompt
    golden = _dense_streams(cfg, kv_bits, prompts)
    eng = _engine(cfg, kv_bits=kv_bits, kv_paging="paged",
                  prefix_cache=prefix)
    assert _streams(eng, prompts) == golden
    st = eng.pool.stats()
    assert st["blocks_in_use"] == st["prefix_blocks"]   # only residents left
    assert (eng.pool.refs == 0).all()                   # refcounts drained
    if prefix:
        # the shared-prefix prompt re-attached resident blocks by reference
        assert st["hits"] >= 1 and st["prefix_attached"] >= 1


def test_paged_prefix_hit_across_rounds_is_bit_identical():
    cfg = _cfg()
    rng = np.random.default_rng(11)
    base = rng.integers(1, cfg.vocab_size, 2 * PREFIX_BLOCK).tolist()
    p1 = base + rng.integers(1, cfg.vocab_size, 3).tolist()
    p2 = base + rng.integers(1, cfg.vocab_size, 5).tolist()

    def run(paging):
        eng = _engine(cfg, kv_bits=4, kv_paging=paging,
                      prefix_cache=1 << 20)
        out = []
        for p in (p1, p2):                   # p2 admits AFTER p1 published
            out += _streams(eng, [p])
        return out, (eng.pool.stats() if paging == "paged" else None)

    paged, st = run("paged")
    dense, _ = run("dense")
    assert paged == dense
    assert st["hits"] == 1 and st["prefix_attached"] == 2
    assert st["tokens_reused"] == 2 * PREFIX_BLOCK


# --------------------------------------------------------- n>1 / COW fork
def test_fork_n_samples_deterministic_and_layout_invariant():
    cfg = _cfg()
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, 19).tolist()
    sp = SamplingParams(temperature=0.9, seed=5, n=3)

    def run(paging):
        eng = _engine(cfg, kv_bits=4, kv_paging=paging, prefill_batch=4,
                      slots=4)
        fan = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=6,
                                           sampling=sp))
        solo = eng.submit(GenerationRequest(
            prompt=prompt, max_new_tokens=6,
            sampling=dataclasses.replace(sp, n=1)))
        eng.run_until_drained()
        return ([tuple(s.result().tokens) for s in fan],
                tuple(solo.result().tokens),
                eng.pool.stats() if paging == "paged" else None)

    p_fan, p_solo, st = run("paged")
    d_fan, d_solo, _ = run("dense")
    assert p_fan == d_fan                     # COW fork == plain expansion
    assert p_fan[0] == p_solo == d_solo       # sample 0 keeps the seed
    assert len(set(p_fan)) == 3               # samples are seeded apart
    assert st["cow_forks"] == 2               # followers shared the prompt
    assert st["blocks_free"] == st["blocks_total"]   # refcounts drained


def test_sample_seed_schedule():
    assert sample_seed(7, 0) == 7
    seeds = [sample_seed(7, i) for i in range(4)]
    assert len(set(seeds)) == 4
    assert all(0 <= s < 2 ** 31 for s in seeds)
    with pytest.raises(ValueError):
        SamplingParams(n=0)


# -------------------------------------------------------- plan axis / meta
def test_plan_kv_paging_roundtrip_and_validation():
    cfg = _cfg()
    _, pol = _deployed(cfg)
    plan = ExecutionPlan.build(cfg, pol, kv_bits=4, kv_paging="paged")
    meta = plan_to_meta(plan)
    assert meta["build"]["kv_paging"] == "paged"
    assert plan_from_meta(meta).kv_paging == "paged"
    # old artifacts predate the key: they must load as dense
    del meta["build"]["kv_paging"]
    assert plan_from_meta(meta).kv_paging == "dense"
    assert "kv_paging=paged" in plan.describe()
    assert "kv_paging" not in ExecutionPlan.build(cfg, pol).describe()

    with pytest.raises(ValueError, match="kv_paging"):
        ExecutionPlan.build(cfg, pol, kv_paging="virtual")
    with pytest.raises(ValueError, match="chunked"):
        ExecutionPlan.build(cfg, pol, prefill_mode="token", kv_paging="paged")


def test_paged_engine_rejects_bad_geometry_and_budget():
    cfg = _cfg()
    with pytest.raises(ValueError, match="max_len"):
        _engine(cfg, kv_bits=16, kv_paging="paged", max_len=60)
    with pytest.raises(ValueError, match="kv_budget_bytes"):
        _engine(cfg, kv_bits=16, kv_paging="dense", kv_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="block"):
        _engine(cfg, kv_bits=16, kv_paging="paged", kv_budget_bytes=16)


# ------------------------------------------------- kernel-level indirection
def test_decode_attention_paged_bit_identical_to_dense_gather():
    rng = np.random.default_rng(3)
    NB, block, Hkv, H, dh, Bsz = 12, 8, 2, 4, 16, 3
    nb = 4                                    # S = 32
    kq = jnp.asarray(rng.integers(-8, 8, (NB, block, Hkv, dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-8, 8, (NB, block, Hkv, dh)), jnp.int8)
    ks = jnp.asarray(rng.random((NB, block, Hkv), np.float32))
    vs = jnp.asarray(rng.random((NB, block, Hkv), np.float32))
    tables = jnp.asarray(rng.permutation(NB)[:Bsz * nb].reshape(Bsz, nb),
                         jnp.int32)
    q = jnp.asarray(rng.standard_normal((Bsz, H, dh)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((Bsz, Hkv, dh)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((Bsz, Hkv, dh)), jnp.float32)
    lengths = jnp.asarray([30, 0, 17], jnp.int32)

    paged = decode_attention_paged(q, kq, vq, ks, vs, tables, kn, vn,
                                   lengths, bs=8, interpret=True)
    dense = decode_attention_pallas(
        q, gather_kv_blocks(kq, tables), gather_kv_blocks(vq, tables),
        gather_kv_blocks(ks, tables), gather_kv_blocks(vs, tables),
        kn, vn, lengths, bs=8, interpret=True)
    assert paged.shape == (Bsz, H, dh)
    assert jnp.array_equal(paged, dense)
    # out-of-range table entries (the pool sentinel) clamp, never NaN
    sentinel = tables.at[:, -1].set(NB + 5)
    out = decode_attention_paged(q, kq, vq, ks, vs, sentinel, kn, vn,
                                 jnp.asarray([24, 0, 17], jnp.int32),
                                 bs=8, interpret=True)
    assert not jnp.isnan(out).any()


def test_pallas_backend_paged_streams_match_dense():
    cfg = _cfg()
    prompts = _prompts(cfg, n=2)
    d = _streams(_engine(cfg, kv_bits=4, kv_paging="dense",
                         backend="pallas"), prompts)
    p = _streams(_engine(cfg, kv_bits=4, kv_paging="paged",
                         backend="pallas"), prompts)
    assert p == d


# ------------------------------------------------- admission under budget
def test_one_budget_gates_admission_and_rejects_oversize():
    cfg = _cfg()
    eng = _engine(cfg, kv_bits=4, kv_paging="paged", slots=4,
                  kv_budget_bytes=None)
    pool = eng.pool
    # shrink to a 3-block pool to make admission the binding constraint
    eng = _engine(cfg, kv_bits=4, kv_paging="paged", slots=4,
                  kv_budget_bytes=3 * pool.block_nbytes)
    assert eng.pool.num_blocks == 3
    # a request that can never fit is rejected at submit, not at admit
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(GenerationRequest(prompt=list(range(1, 30)),
                                     max_new_tokens=10))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, 6).tolist() for _ in range(5)]
    streams = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
               for p in prompts]
    peak = 0
    for _ in range(500):
        eng.engine_step()
        peak = max(peak, sum(r is not None for r in eng.active))
        if not (eng.queue or any(r is not None for r in eng.active)):
            break
    # 6+4 tokens = 2 blocks each: the 3-block budget holds ONE request at a
    # time even though 4 slots are free — admission is byte-gated
    assert peak == 1
    assert all(len(s.result().tokens) == 4 for s in streams)
    done = eng.pop_done()
    assert all(r.finish_reason == "length" for r in done)
    # gauges surfaced through the metrics pipe and drain with pop_summary
    s = eng.metrics.summary()
    assert s["kv"]["blocks_total"] == 3
    assert "kv:" in eng.metrics.report()
    eng.metrics.pop_summary()
    assert "kv" not in eng.metrics.summary()


def test_paged_eviction_keeps_streams_identical_under_reuse():
    """Registry residents evicted under pressure must only cost recompute,
    never correctness: a prompt whose published blocks were evicted serves
    the same stream as a cold dense engine."""
    cfg = _cfg()
    rng = np.random.default_rng(9)
    pa = rng.integers(1, cfg.vocab_size, 2 * PREFIX_BLOCK + 1).tolist()
    pb = rng.integers(1, cfg.vocab_size, 2 * PREFIX_BLOCK + 1).tolist()

    def run(paging, budget_blocks=None):
        kw = {}
        if paging == "paged" and budget_blocks:
            probe = _engine(cfg, kv_bits=4, kv_paging="paged")
            kw["kv_budget_bytes"] = budget_blocks * probe.pool.block_nbytes
        eng = _engine(cfg, kv_bits=4, kv_paging=paging,
                      prefix_cache=1 << 20, **kw)
        out = []
        for p in (pa, pb, pa):       # pb's blocks push pa's out of the pool
            out += _streams(eng, [p], max_new=3)
        return out, (eng.pool.stats() if paging == "paged" else None)

    paged, st = run("paged", budget_blocks=4)
    dense, _ = run("dense")
    assert paged == dense
    assert st["evictions"] >= 1
