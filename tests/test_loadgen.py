"""Virtual-clock load-harness simulation tests (DESIGN.md §12).

Every timing assertion here is EXACT: the engine runs against an injected
``VirtualClock`` that only advances when the load generator charges its
deterministic ``VirtualCost`` model, so TTFT, queue wait, deadline shedding
and cancellation timing are pure functions of the op sequence — no
``time.sleep`` anywhere, and no wall-clock value ever appears in an
assertion. The wall-clock path shares all of this code with the default
``time.monotonic`` clock (``benchmarks/serve_load.py``); what changes is
only who advances time.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced
from repro.deploy import ExecutionPlan
from repro.models import api
from repro.serving import (SLO, Arrival, GenerationRequest, ServeMetrics,
                           ServingEngine, VirtualClock, VirtualCost,
                           Workload, bootstrap_summary, make_arrivals,
                           run_load, run_trials, trace_arrivals)

KEY = jax.random.PRNGKey(0)

#: the deterministic cost model used throughout: decode step 10ms, prefill
#: 1ms per prompt token.
COST = VirtualCost(decode_step_s=0.01, prefill_per_token_s=0.001)
D, P = COST.decode_step_s, COST.prefill_per_token_s


@pytest.fixture(scope="module")
def fp_setup():
    cfg = reduced(get_config("stablelm-3b"))
    plan = ExecutionPlan.build(cfg, None)
    return api.init_model(cfg, KEY), plan, cfg


def _engine(fp_setup, **kw):
    params, plan, _ = fp_setup
    kw.setdefault("clock", VirtualClock())
    return ServingEngine(params, plan, slots=kw.pop("slots", 2),
                         max_len=kw.pop("max_len", 64), **kw)


def _arrival(t, plen, max_new, vocab, **kw):
    rng = np.random.default_rng(plen * 1000 + max_new)
    return Arrival(t=t, prompt=rng.integers(1, vocab, plen).astype(np.int32),
                   max_new_tokens=max_new, **kw)


# ------------------------------------------------------------ VirtualClock
def test_virtual_clock_advances_and_rejects_rewind():
    clk = VirtualClock(start=5.0)
    assert clk() == 5.0
    assert clk.advance(1.5) == 6.5
    assert clk.advance_to(6.0) == 6.5      # no-op: already past
    assert clk.advance_to(10.0) == 10.0
    with pytest.raises(ValueError):
        clk.advance(-0.1)


def test_engine_accepts_injected_clock_everywhere(fp_setup):
    """One clock serves engine, scheduler and metrics: a virtual advance is
    visible in the metrics wall window without any wall time passing."""
    clk = VirtualClock()
    eng = _engine(fp_setup, clock=clk)
    assert eng.scheduler._clock is clk
    clk.advance(2.5)
    assert eng.metrics.summary()["wall_s"] == pytest.approx(2.5)


# ------------------------------------------------- exact TTFT / queue wait
def test_ttft_and_queue_wait_exact_single_slot(fp_setup):
    """slots=1, two arrivals at t=0: r0 runs first; every stamp of r1's
    life is a closed-form function of the cost model.

    Step 1 admits+prefills r0 (emits its first token, then one decode
    token); r0 (max_new=3) finishes during step 2. Step 3 admits r1.
    """
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a0 = _arrival(0.0, plen=5, max_new=3, vocab=cfg.vocab_size)
    a1 = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size)
    res = run_load(eng, [a0, a1], cost=COST)
    r0, r1 = sorted(res.records, key=lambda r: r.index)

    step1 = D + P * 5            # admit+prefill r0, decode
    step2 = D                    # r0's last decode token
    step3 = D + P * 4            # admit+prefill r1, decode
    assert r0.queue_wait_s == pytest.approx(0.0)
    assert r0.ttft_s == pytest.approx(step1)
    assert r0.finish_reason == "length"
    # r1 sat queued while r0's two steps ran
    assert r1.queue_wait_s == pytest.approx(step1 + step2)
    assert r1.ttft_s == pytest.approx(step1 + step2 + step3)
    assert r1.finish_reason == "length"
    # r1 (max_new=2) emits both tokens in its prefill step: prefill emits
    # token 1, the same step's batched decode emits token 2
    assert r1.token_times == pytest.approx(
        [step1 + step2 + step3, step1 + step2 + step3])
    assert res.duration_s == pytest.approx(step1 + step2 + step3)


def test_inter_token_gaps_equal_step_cost(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a = _arrival(0.0, plen=6, max_new=5, vocab=cfg.vocab_size)
    res = run_load(eng, [a], cost=COST)
    (rec,) = res.records
    gaps = rec.gaps_s
    # first gap is 0 (prefill token + decode token share a step stamp),
    # every later gap is exactly one decode step
    assert gaps[0] == pytest.approx(0.0)
    assert gaps[1:] == pytest.approx([D] * (len(gaps) - 1))


def test_idle_engine_jumps_to_next_arrival(fp_setup):
    """A gap in the arrival process costs zero steps: the generator advances
    the virtual clock straight to the next arrival."""
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a0 = _arrival(0.0, plen=4, max_new=1, vocab=cfg.vocab_size)
    a1 = _arrival(100.0, plen=4, max_new=1, vocab=cfg.vocab_size)
    res = run_load(eng, [a0, a1], cost=COST)
    r0, r1 = sorted(res.records, key=lambda r: r.index)
    assert r1.submit_t == pytest.approx(100.0)
    assert r1.ttft_s == pytest.approx(D + P * 4)
    assert res.steps == 2


# ------------------------------------------------------- deadline shedding
def test_deadline_shed_timing_exact(fp_setup):
    """r1's deadline expires while r0 monopolizes the only slot: r1 is shed,
    never admitted, and the shed verdict lands at the first admit attempt
    past the deadline — deterministically."""
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    # r0 runs 8 decode steps ~ 0.08s+; r1's deadline is 0.05s
    a0 = _arrival(0.0, plen=4, max_new=8, vocab=cfg.vocab_size)
    a1 = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size,
                  deadline_s=0.05)
    res = run_load(eng, [a0, a1], cost=COST)
    r0, r1 = sorted(res.records, key=lambda r: r.index)
    assert r0.finish_reason == "length"
    assert r1.finish_reason == "shed"
    assert r1.token_times == []            # never produced anything
    assert res.summary(SLO(ttft_s=1, itl_s=1))["n_shed"] == 1
    # shed requests are SLO failures: goodput counts them in the denominator
    assert res.summary(SLO(ttft_s=1, itl_s=1))["goodput"] == pytest.approx(
        0.5)


def test_deadline_survives_when_slot_frees_in_time(fp_setup):
    """Same shape, generous deadline: r1 is admitted normally — the shed
    path depends only on virtual time, not on host speed."""
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a0 = _arrival(0.0, plen=4, max_new=8, vocab=cfg.vocab_size)
    a1 = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size,
                  deadline_s=10.0)
    res = run_load(eng, [a0, a1], cost=COST)
    r1 = sorted(res.records, key=lambda r: r.index)[1]
    assert r1.finish_reason == "length"
    # r0 (max_new=8) runs 7 steps: prefill step emits 2 tokens, then 6
    # decode steps; r1 admits at the start of the step after
    assert r1.queue_wait_s == pytest.approx((D + P * 4) + 6 * D)


# ---------------------------------------------------- cancellation timing
def test_injected_cancel_after_exact_token_count(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a = _arrival(0.0, plen=4, max_new=10, vocab=cfg.vocab_size,
                 cancel_after_tokens=3)
    res = run_load(eng, [a], cost=COST)
    (rec,) = res.records
    assert rec.finish_reason == "cancelled"
    assert rec.injected_cancel
    assert len(rec.tokens) == 3
    # tokens 1+2 in the prefill step, token 3 one decode step later; the
    # cancel lands in the same pump iteration that observed token 3
    assert rec.finish_t == pytest.approx((D + P * 4) + D)
    # injected cancels leave the goodput denominator
    s = res.summary(SLO(ttft_s=1, itl_s=1))
    assert s["n_counted"] == 0 and s["n_cancelled"] == 1


def test_cancel_frees_slot_for_queued_work_at_exact_time(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a0 = _arrival(0.0, plen=4, max_new=10, vocab=cfg.vocab_size,
                  cancel_after_tokens=2)
    a1 = _arrival(0.0, plen=4, max_new=1, vocab=cfg.vocab_size)
    res = run_load(eng, [a0, a1], cost=COST)
    r1 = sorted(res.records, key=lambda r: r.index)[1]
    # a0 emits 2 tokens in its first step and is cancelled right after it;
    # a1 admits at the start of the next step
    assert r1.queue_wait_s == pytest.approx(D + P * 4)
    assert r1.finish_reason == "length"


# ------------------------------------------------------ priority + rejects
def test_priority_admission_order_under_contention(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a0 = _arrival(0.0, plen=4, max_new=4, vocab=cfg.vocab_size)
    lo = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size, priority=0)
    hi = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size, priority=5)
    res = run_load(eng, [a0, lo, hi], cost=COST)
    r_lo, r_hi = sorted(res.records, key=lambda r: r.index)[1:]
    assert r_hi.queue_wait_s < r_lo.queue_wait_s
    assert r_hi.token_times[0] < r_lo.token_times[0]


def test_bounded_queue_rejections_deterministic(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1, max_queue=1)
    arrivals = [_arrival(0.0, plen=4, max_new=6, vocab=cfg.vocab_size)
                for _ in range(4)]
    res = run_load(eng, arrivals, cost=COST)
    s = res.summary(SLO(ttft_s=10, itl_s=10))
    # all four arrive before the first engine step, so nothing has been
    # admitted yet: the queue holds 1 and the other 3 bounce with
    # QueueFullError; the first request completes once the pump runs
    assert s["n_rejected"] == 3
    assert s["n_completed"] == 1
    assert s["goodput"] == pytest.approx(0.25)


# ------------------------------------------------------------- determinism
def test_full_mixed_run_is_deterministic(fp_setup):
    """The whole harness — Poisson arrivals, shared prefix, priorities,
    deadlines, cancels, sampled decoding — replayed twice from the same
    seed produces identical records, stamps and summaries."""
    params, plan, cfg = fp_setup
    w = Workload(n_requests=12, rate_rps=30.0, vocab=cfg.vocab_size,
                 prompt_len=(4, 10), new_tokens=(2, 6),
                 shared_prefix_frac=0.3, shared_prefix_len=8,
                 sampled_frac=0.5, priorities=(0, 1, 2),
                 deadline_frac=0.3, deadline_s=0.2,
                 cancel_frac=0.25, cancel_after_tokens=2)

    def one_run():
        eng = ServingEngine(params, plan, slots=2, max_len=64,
                            clock=VirtualClock())
        return run_load(eng, make_arrivals(w, seed=7), cost=COST)

    r1, r2 = one_run(), one_run()
    slo = SLO(ttft_s=0.1, itl_s=0.05)
    assert r1.summary(slo) == r2.summary(slo)
    for a, b in zip(r1.records, r2.records):
        assert a.tokens == b.tokens
        assert a.token_times == b.token_times
        assert a.finish_reason == b.finish_reason


def test_make_arrivals_deterministic_and_distinct_by_seed():
    w = Workload(n_requests=6, rate_rps=10.0, vocab=64)
    a = make_arrivals(w, seed=3)
    b = make_arrivals(w, seed=3)
    c = make_arrivals(w, seed=4)
    assert [x.t for x in a] == [x.t for x in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [x.t for x in a] != [x.t for x in c]
    # arrival times are a Poisson process: strictly increasing offsets
    assert all(t1 < t2 for t1, t2 in zip([x.t for x in a],
                                         [x.t for x in a][1:]))


def test_trace_replay_pins_times_and_overrides():
    w = Workload(vocab=64, prompt_len=(4, 8), new_tokens=(2, 4))
    trace = [0.5, {"t": 0.1, "prompt_len": 7, "max_new_tokens": 9,
                   "priority": 3, "deadline_s": 1.5,
                   "cancel_after_tokens": 2}]
    arrivals = trace_arrivals(trace, w, seed=0)
    assert [a.t for a in arrivals] == [0.1, 0.5]    # sorted by time
    pinned = arrivals[0]
    assert pinned.prompt_len == 7
    assert pinned.max_new_tokens == 9
    assert pinned.priority == 3
    assert pinned.deadline_s == 1.5
    assert pinned.cancel_after_tokens == 2
    # same trace + seed replays identically
    again = trace_arrivals(trace, w, seed=0)
    assert all(np.array_equal(a.prompt, b.prompt)
               for a, b in zip(arrivals, again))


# ------------------------------------------------------- goodput math + CI
def test_goodput_splits_on_slo_threshold(fp_setup):
    """The same run scored under a tight vs generous TTFT SLO: goodput is
    an exact ratio either way (virtual stamps make the split deterministic)."""
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    arrivals = [_arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size)
                for _ in range(3)]
    res = run_load(eng, arrivals, cost=COST)
    recs = sorted(res.records, key=lambda r: r.ttft_s)
    # each request runs exactly one (D + 4P) step, back to back
    assert recs[0].ttft_s == pytest.approx(D + 4 * P)
    assert recs[2].ttft_s == pytest.approx(3 * (D + 4 * P))
    mid = 2 * (D + 4 * P) + 1e-9
    assert res.summary(SLO(ttft_s=mid, itl_s=1))["goodput"] == \
        pytest.approx(2 / 3)
    assert res.summary(SLO(ttft_s=10, itl_s=1))["goodput"] == 1.0


def test_bootstrap_summary_deterministic_with_valid_interval(fp_setup):
    params, plan, cfg = fp_setup
    w = Workload(n_requests=6, rate_rps=40.0, vocab=cfg.vocab_size,
                 prompt_len=(4, 8), new_tokens=(2, 4))

    def make_engine():
        return ServingEngine(params, plan, slots=1, max_len=64,
                             clock=VirtualClock())

    trials = run_trials(make_engine, w, n_trials=2, cost=COST)
    slo = SLO(ttft_s=2 * (D + 8 * P), itl_s=1.0)
    s1 = bootstrap_summary(trials, slo, n_boot=100, seed=5)
    s2 = bootstrap_summary(trials, slo, n_boot=100, seed=5)
    assert s1 == s2
    g = s1["goodput"]
    assert 0.0 <= g["lo"] <= g["mean"] <= g["hi"] <= 1.0
    assert s1["n_offered"] == 12
    for key in ("ttft_p50_ms", "ttft_p99_ms", "itl_p99_ms"):
        ci = s1[key]
        assert ci["lo"] <= ci["mean"] <= ci["hi"]


def test_virtual_mode_requires_virtual_clock(fp_setup):
    params, plan, cfg = fp_setup
    eng = ServingEngine(params, plan, slots=1, max_len=64)  # system clock
    with pytest.raises(TypeError, match="VirtualClock"):
        run_load(eng, [_arrival(0.0, 4, 1, cfg.vocab_size)], cost=COST)


def test_run_load_raises_on_step_budget(fp_setup):
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    arrivals = [_arrival(0.0, plen=4, max_new=8, vocab=cfg.vocab_size)
                for _ in range(3)]
    with pytest.raises(RuntimeError, match="max_steps"):
        run_load(eng, arrivals, cost=COST, max_steps=2)


def test_metrics_share_virtual_clock(fp_setup):
    """ServeMetrics shares the virtual clock: after a simulated run its
    wall window equals the generator's virtual duration exactly (the
    metrics recorder was constructed at virtual t=0)."""
    _, _, cfg = fp_setup
    eng = _engine(fp_setup, slots=1)
    a = _arrival(0.0, plen=4, max_new=2, vocab=cfg.vocab_size)
    res = run_load(eng, [a], cost=COST)
    s = eng.metrics.summary()
    assert s["wall_s"] == pytest.approx(res.duration_s)
    # queue-wait samples flow through the same clock: the lone request
    # admitted immediately, so its recorded wait is exactly zero
    assert s["queue_wait_p50_ms"] == pytest.approx(0.0)
