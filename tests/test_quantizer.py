"""Quantizer unit + property tests — the paper's §4.1 core."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.quantizer import (dequantize, fake_quant, lsq_quantize,
                                  qrange, quantize_to_int)


def test_qrange_paper_grid():
    # paper: l_min = -2^{k-1}+1, l_max = 2^{k-1}; k=4 -> [-7, 8]
    assert qrange(4) == (-7, 8)
    assert qrange(2) == (-1, 2)
    # k=8 deploys in an int8 carrier: [-127, 127] (DESIGN.md §6)
    assert qrange(8) == (-127, 127)


def test_paper_worked_example():
    """§4.1 case study: x=(0.2,0.9), s=1 -> STE grad < 0, MSE grad > 0.

    The paper's point: decreasing s to 0.9 improves Q[x], so the gradient
    should be POSITIVE (descend -> smaller s); STE gets the sign wrong.
    Raw values: STE -0.1, MSE +0.2 (ours scale by documented normalizers).
    """
    x = jnp.array([0.2, 0.9])
    s = jnp.array(1.0)
    g_ste = jax.grad(lambda s_: jnp.sum(lsq_quantize(x, s_, 4, "ste")))(s)
    g_mse = jax.grad(lambda s_: jnp.sum(lsq_quantize(x, s_, 4, "mse")))(s)
    assert g_ste < 0, "STE-based gradient has the (wrong) negative sign"
    assert g_mse > 0, "MSE-based gradient must be positive here"
    # exact values with normalizers: ste/-sqrt(2*8), mse: 0.2/2
    np.testing.assert_allclose(float(g_ste), -0.1 / np.sqrt(16), rtol=1e-5)
    np.testing.assert_allclose(float(g_mse), 0.1, rtol=1e-5)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(-50, 50, allow_nan=False, width=32),
                min_size=1, max_size=64),
       st.floats(0.01, 4.0), st.sampled_from([2, 4, 8]))
def test_quantization_properties(xs, s, bits):
    """Invariants: output on grid, bounded error in-range, idempotence."""
    x = jnp.array(xs, jnp.float32)
    s = jnp.float32(s)
    q = lsq_quantize(x, s, bits, "mse")
    qmin, qmax = qrange(bits)
    codes = np.asarray(q / s)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.all(codes >= qmin - 1e-4) and np.all(codes <= qmax + 1e-4)
    in_range = (np.asarray(x) / float(s) >= qmin) & \
               (np.asarray(x) / float(s) <= qmax)
    err = np.abs(np.asarray(q) - np.asarray(x))
    assert np.all(err[in_range] <= float(s) / 2 + 1e-5)
    q2 = lsq_quantize(q, s, bits, "mse")
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.floats(0.05, 2.0), st.sampled_from([4, 8]))
def test_mse_gradient_matches_numeric(n, s, bits):
    """The MSE-mode scale gradient descends the true quantization MSE."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    s = jnp.float32(s)

    def mse(s_):
        q = lsq_quantize(x, s_, bits, "mse")
        return jnp.sum((q - x) ** 2)

    g = jax.grad(lambda s_: jnp.sum(lsq_quantize(x, s_, bits, "mse")))(s)
    eps = 1e-4
    num = (float(mse(s + eps)) - float(mse(s - eps))) / (2 * eps) / x.size
    # grads agree when no element sits on a rounding boundary
    if abs(num - float(g)) > 0.05 * (abs(num) + abs(float(g)) + 1e-3):
        z = np.asarray(x) / float(s)
        near_boundary = np.any(np.abs(z - np.round(z) - 0.5) < 1e-2) or \
            np.any(np.abs(np.abs(z) - qrange(bits)[1]) < 1e-2)
        assert near_boundary, (num, float(g))


def test_x_gradient_straight_through():
    x = jnp.array([-100.0, -0.4, 0.0, 0.7, 100.0])
    s = jnp.array(1.0)
    g = jax.grad(lambda x_: jnp.sum(lsq_quantize(x_, s, 4, "mse")))(x)
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 1, 0], atol=1e-6)


def test_per_row_scales():
    x = jnp.ones((4, 6))
    s = jnp.array([[0.1], [0.2], [0.4], [1.0]])
    q = lsq_quantize(x, s, 8, "mse")
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), atol=0.51)
    g = jax.grad(lambda s_: jnp.sum((lsq_quantize(x, s_, 8, "mse") - x) ** 2)
                 )(s)
    assert g.shape == s.shape


def test_int_roundtrip_matches_fake_quant():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    for bits in (4, 8):
        s = jnp.float32(float(np.abs(x).max()) / qrange(bits)[1])
        fake = fake_quant(x, s, bits, "mse")
        codes = quantize_to_int(x, s, bits)
        np.testing.assert_allclose(np.asarray(dequantize(codes, s)),
                                   np.asarray(fake), atol=1e-6)
