"""Substrate tests: optimizer, schedule, data pipeline, checkpoint manager,
gradient compression, straggler watchdog, elastic helpers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import lm_batches
from repro.data.synthetic import SyntheticLM
from repro.distributed.compression import compressed_grad_mean
from repro.launch.train import StragglerWatchdog
from repro.optim import adam_init, adam_update, group_for_path, \
    linear_warmup_decay


# ------------------------------------------------------------------ optimizer
def test_adam_param_groups():
    params = {"layers": {"ffn": {"w1": {"w": jnp.ones((4, 4)),
                                        "s_w": jnp.ones((1, 4)),
                                        "s_a": jnp.ones(())}}}}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    groups = {"/".join(str(getattr(p, "key", p)) for p in path):
              group_for_path(path) for path, _ in flat}
    assert groups["layers/ffn/w1/w"] == "weights"
    assert groups["layers/ffn/w1/s_w"] == "weight_scale"
    assert groups["layers/ffn/w1/s_a"] == "act_scale"


def test_adam_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adam_init(params)
    sched = lambda step: jnp.float32(1.0)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = adam_update(params, grads, opt,
                                  lr_by_group={"weights": 0.1,
                                               "act_scale": 0.1,
                                               "weight_scale": 0.1},
                                  schedule_fn=sched)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_scales_stay_positive():
    params = {"s_a": jnp.float32(1e-6)}
    opt = adam_init(params)
    for _ in range(10):
        params, opt = adam_update(params, {"s_a": jnp.float32(1.0)}, opt,
                                  lr_by_group={"weights": 0.1,
                                               "act_scale": 0.5,
                                               "weight_scale": 0.1},
                                  schedule_fn=lambda s: jnp.float32(1.0))
    assert float(params["s_a"]) >= 0.99e-8  # clamp, f32 rounding


def test_schedule_shape():
    f = linear_warmup_decay(100, 0.1)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1.0)
    assert float(f(jnp.int32(55))) == pytest.approx(0.5, abs=1e-2)
    assert float(f(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------------ data
def test_lm_data_deterministic_and_sharded():
    a = SyntheticLM(256, 16, 8, seed=3).batch(5)
    b = SyntheticLM(256, 16, 8, seed=3).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    h0 = SyntheticLM(256, 16, 8, seed=3, host_index=0, num_hosts=2).batch(0)
    h1 = SyntheticLM(256, 16, 8, seed=3, host_index=1, num_hosts=2).batch(0)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_lm_data_has_learnable_structure():
    """Markov stream: conditional entropy << vocab entropy."""
    d = SyntheticLM(256, 512, 4, seed=0, branching=4)
    toks = d.batch(0)["tokens"].reshape(-1)
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), set()).add(int(b))
    avg_branching = np.mean([len(v) for v in pairs.values()])
    assert avg_branching <= 8  # far below vocab=256


def test_prefetcher():
    it = lm_batches(64, 8, 4, prefetch=True)
    batches = [next(iter(it)) for _ in range(3)]
    assert all(b["tokens"].shape == (4, 8) for b in batches)


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (10, 20, 30):
        mgr.save(s, state)
    assert mgr.all_steps() == [20, 30]
    restored, step = mgr.restore(state)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_crash_safety(tmp_path):
    """A half-written temp dir must not shadow the last good step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones(3)}
    mgr.save(1, state)
    os.makedirs(os.path.join(str(tmp_path), ".tmp_crashed"), exist_ok=True)
    restored, step = mgr.restore(state)
    assert step == 1 and restored is not None


def test_checkpoint_missing_dir_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    restored, step = mgr.restore({"w": jnp.ones(2)})
    assert restored is None and step is None


# ------------------------------------------------------------------ watchdog
def test_straggler_watchdog():
    w = StragglerWatchdog(factor=3.0)
    for _ in range(10):
        assert not w.observe(0, 1.0)
    assert w.observe(11, 10.0)
    assert w.flagged


# ------------------------------------------------------------------ compression
def test_int8_error_feedback_compression():
    """shard_map int8+EF reduction: mean error -> 0 over repeated steps."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((4, 8)).astype(np.float32))}

    @jax.jit
    def reduce_once(grads, err):
        def f(gr, er):
            return compressed_grad_mean(gr, ("data",), "int8_ef", er)
        return shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                         out_specs=(P("data"), P("data")))(
            jax.tree.map(lambda a: a[None], grads),
            jax.tree.map(lambda a: a[None], err))

    err = jax.tree.map(jnp.zeros_like, g)
    total_exact = jnp.zeros_like(g["w"])
    total_comp = jnp.zeros_like(g["w"])
    for i in range(50):
        mean, err_ = reduce_once(g, err)
        err = jax.tree.map(lambda a: a[0], err_)
        total_comp = total_comp + mean["w"][0]
        total_exact = total_exact + g["w"]
    # error feedback: accumulated compressed sum tracks the exact sum
    rel = float(jnp.max(jnp.abs(total_comp - total_exact))
                / jnp.max(jnp.abs(total_exact)))
    assert rel < 0.02, rel


def test_bf16_compression_close():
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(1)
                          .standard_normal((16,)).astype(np.float32))}

    def f(gr):
        m, _ = compressed_grad_mean(gr, ("data",), "bf16")
        return m
    out = shard_map(f, mesh=mesh, in_specs=(P("data"),),
                    out_specs=P("data"))(jax.tree.map(lambda a: a[None], g))
    np.testing.assert_allclose(np.asarray(out["w"][0]), np.asarray(g["w"]),
                               rtol=1e-2, atol=1e-2)


# ------------------------------------------------------------------ elastic
def test_elastic_rebalance():
    from repro.launch.elastic import rebalance_batch
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert rebalance_batch(256, mesh) == 256
