"""MultiTenantEngine tests: fair share, quotas, shared rid space
(DESIGN.md §14).

The fair-share property is checked as the DRR invariant itself, on a
``VirtualClock`` so the step sequence is exact: while two tenants both have
work, neither runs more than its deficit bound of consecutive steps — so a
modest encoder tenant finishes long before a flooded decoder tenant drains,
instead of starving behind it. Quota and lifecycle tests pin the submit-side
isolation: a tenant spending its token budget is rejected without touching
its neighbours, and every rid names a request process-wide.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.models.bert import init_bert_classifier, tinybert_config
from repro.serving import (EncodeRequest, GenerationRequest,
                           MultiTenantEngine, QuotaExceededError,
                           ServingEngine, VirtualClock)

KEY = jax.random.PRNGKey(0)
_CACHE = {}


def _encoder_model():
    if "enc" not in _CACHE:
        cfg = tinybert_config(num_classes=2, layers=2, d=64, heads=4,
                              d_ff=128, vocab=256, name="tinybert-test")
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=cfg.num_layers)
        plan = ExecutionPlan.build(cfg, pol, backend="reference", act_bits=4,
                                   mode="encoder", prefill_batch=4)
        _CACHE["enc"] = deploy(init_bert_classifier(cfg, 2, KEY), plan)
    return _CACHE["enc"]


def _decoder_model():
    if "dec" not in _CACHE:
        cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=cfg.num_layers)
        plan = ExecutionPlan.build(cfg, pol, backend="reference", act_bits=4)
        _CACHE["dec"] = (deploy(api.init_model(cfg, KEY), plan), cfg)
    return _CACHE["dec"]


def _enc_req(plen, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return EncodeRequest(tokens=rng.integers(1, 256, plen), **kw)


def _gen_req(plen, max_new, vocab, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return GenerationRequest(prompt=rng.integers(1, vocab, plen
                                                 ).astype(np.int32),
                             max_new_tokens=max_new, **kw)


def _mt(quantum=32):
    mt = MultiTenantEngine(clock=VirtualClock(), quantum_tokens=quantum)
    mt.add_tenant("cls", _encoder_model(), slots=2, max_len=64)
    dec, _ = _decoder_model()
    mt.add_tenant("gen", dec, slots=2, max_len=64)
    return mt


# ------------------------------------------------------- fair share / DRR
def test_no_starvation_under_adversarial_flood():
    """A flooded decoder tenant must not starve a modest encoder tenant:
    while both have work, no tenant runs more than its DRR deficit bound of
    consecutive steps, and the modest tenant finishes while the flood is
    still draining."""
    quantum = 32
    mt = _mt(quantum=quantum)
    dec, cfg = _decoder_model()
    # adversarial: the flood is submitted FIRST and outweighs cls ~10x
    flood = [mt.submit(_gen_req(8, 6, cfg.vocab_size, seed=i),
                       tenant="gen") for i in range(10)]
    done_at = {}
    cls = [mt.submit_encode(_enc_req(8, seed=i), tenant="cls",
                            on_result=lambda rid, v: done_at.setdefault(
                                rid, len(trace)))
           for i in range(4)]

    # instrument which tenant each engine_step serves
    trace = []
    for name, t in mt.tenants.items():
        real = t.engine.engine_step
        t.engine.engine_step = (lambda n=name, f=real: (trace.append(n),
                                                        f())[1])

    steps = mt.run_until_drained()
    assert steps == len(trace)
    for h in cls:
        assert h.finish_reason == "done"
    for s in flood:
        assert s.request.finish_reason in ("length", "stop")

    # the modest tenant resolved while the flood still had work queued
    last_cls = max(done_at[h.rid] for h in cls)
    assert "gen" in trace[last_cls:]         # the flood kept draining after
    assert last_cls < len(trace) / 2         # ...and cls never waited on it

    # DRR bound: each step pays >= 1 token against a deficit of at most
    # weight * quantum (+ one step of overshoot), so a tenant's turn can
    # never exceed quantum + 1 consecutive steps while others wait
    run_len, prev = 0, None
    for name in trace:
        run_len = run_len + 1 if name == prev else 1
        prev = name
        assert run_len <= quantum + 1


def test_idle_tenants_cost_nothing():
    """Work conservation: with only one tenant active, every step serves it
    (idle tenants are skipped, their deficit reset)."""
    mt = _mt()
    h = mt.submit_encode(_enc_req(6), tenant="cls")
    mt.engine_step()
    assert h.finish_reason == "done"
    assert mt.tenants["gen"].deficit == 0.0
    assert mt.engine_step() == []            # fully drained: no-op


def test_handle_pumps_the_drr_loop():
    """Handles submitted through the MT engine pump the DRR loop, not just
    their own tenant."""
    mt = _mt()
    h = mt.submit_encode(_enc_req(6), tenant="cls")
    res = h.result()
    assert res.finish_reason == "done" and res.value.shape == (2,)


# ----------------------------------------------------------------- quotas
def test_token_budget_quota_rejects_and_releases():
    mt = _mt()
    mt.tenants["cls"].token_budget = 20
    h1 = mt.submit_encode(_enc_req(8, seed=1), tenant="cls")
    h2 = mt.submit_encode(_enc_req(8, seed=2), tenant="cls")
    assert mt.tenants["cls"].outstanding_tokens == 16
    with pytest.raises(QuotaExceededError):
        mt.submit_encode(_enc_req(8, seed=3), tenant="cls")
    # the rejection consumed nothing — and the other tenant is untouched
    assert mt.tenants["cls"].outstanding_tokens == 16
    dec, cfg = _decoder_model()
    mt.submit(_gen_req(4, 2, cfg.vocab_size), tenant="gen")

    mt.run_until_drained()
    assert h1.finish_reason == h2.finish_reason == "done"
    assert mt.tenants["cls"].outstanding_tokens == 0     # budget released
    mt.submit_encode(_enc_req(8, seed=3), tenant="cls")  # fits again


def test_generation_quota_counts_prompt_plus_output():
    mt = _mt()
    dec, cfg = _decoder_model()
    mt.tenants["gen"].token_budget = 10
    mt.submit(_gen_req(4, 3, cfg.vocab_size), tenant="gen")   # cost 7
    with pytest.raises(QuotaExceededError):
        mt.submit(_gen_req(2, 2, cfg.vocab_size), tenant="gen")  # 7+4 > 10
    mt.submit(_gen_req(1, 2, cfg.vocab_size), tenant="gen")      # 7+3 fits


def test_cancel_releases_quota():
    mt = _mt()
    mt.tenants["cls"].token_budget = 10
    h = mt.submit_encode(_enc_req(8), tenant="cls")
    assert mt.cancel(h.rid)
    assert h.finish_reason == "cancelled"
    assert mt.tenants["cls"].outstanding_tokens == 0
    assert not mt.cancel(12345)              # unknown rid anywhere


# ------------------------------------------------- shared rid space / misc
def test_shared_rid_space_and_pop_done_order():
    mt = _mt()
    dec, cfg = _decoder_model()
    handles = [mt.submit_encode(_enc_req(6, seed=1), tenant="cls"),
               mt.submit(_gen_req(4, 2, cfg.vocab_size), tenant="gen"),
               mt.submit_encode(_enc_req(7, seed=2), tenant="cls")]
    rids = [h.rid if hasattr(h, "rid") else h.request.rid for h in handles]
    assert rids == sorted(set(rids))         # globally unique, increasing
    mt.run_until_drained()
    done = mt.pop_done()
    assert [r.rid for r in done] == sorted(r.rid for r in done)
    assert len(done) == 3
    assert mt.pop_done() == []               # drained


def test_registry_validation():
    mt = MultiTenantEngine(clock=VirtualClock())
    mt.add_tenant("a", _encoder_model())
    with pytest.raises(ValueError, match="already registered"):
        mt.add_tenant("a", _encoder_model())
    with pytest.raises(ValueError, match="weight"):
        mt.add_tenant("b", _encoder_model(), weight=0)
    with pytest.raises(KeyError, match="unknown tenant"):
        mt.submit_encode(_enc_req(4), tenant="nope")
    with pytest.raises(ValueError, match="quantum"):
        MultiTenantEngine(clock=VirtualClock(), quantum_tokens=0)
