"""Property tests for the Scheduler (DESIGN.md §7/§10/§12).

Model-based: a driver replays an arbitrary interleaving of
submit/advance/admit/complete/cancel/pop_done/pop_shed against a Scheduler
on a VirtualClock, re-checking the lifecycle invariants after every step:

* **conservation** — every accepted request is in exactly ONE place at any
  time (queued, active, done-pending, shed-pending, drained-done,
  drained-shed, or cancelled); nothing is ever lost or double-delivered.
* **admission order** — each admit() round places requests in priority
  order, and within a priority level admission follows submit order (FIFO);
  if free slots remain after admit(), the queue must be empty.
* **query consistency** — ``has_work``/``queue_depth``/``num_active`` agree
  with the actual queue/slot/shed contents.

The same driver runs under two generators: a seeded numpy RNG (always runs,
keeps local coverage) and hypothesis ``@given`` (richer shrinking search in
CI; skips cleanly when hypothesis is absent via the compat shim).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import (GenerationRequest, QueueFullError, Scheduler,
                           VirtualClock)

#: (code, a, b) operation vocabulary shared by both generators
N_OPCODES = 7
DEADLINES = (None, 0.05, 10.0)   # none / sheds under advance / never sheds


def _req(priority, deadline):
    return GenerationRequest(prompt=np.array([1, 2], dtype=np.int32),
                             max_new_tokens=1, priority=priority,
                             deadline_s=deadline)


class _Model:
    """External bookkeeping: where the driver believes every request is."""

    def __init__(self):
        self.accepted = set()
        self.rejected = set()
        self.drained_done = set()
        self.drained_shed = set()
        self.cancelled = set()
        self.submit_order = {}           # rid -> global submit counter
        self.last_admitted = {}          # priority -> last admitted counter
        self._n = 0

    def on_accept(self, req):
        self.accepted.add(req.rid)
        self.submit_order[req.rid] = self._n
        self._n += 1

    def check(self, sched):
        queued = {r.rid for r in sched.queue}
        active = {r.rid for r in sched.active if r is not None}
        done = {r.rid for r in sched.done}
        shed = {r.rid for r in sched._shed}
        buckets = [queued, active, done, shed, self.drained_done,
                   self.drained_shed, self.cancelled]
        union = set().union(*buckets)
        assert union == self.accepted, (
            f"lost: {self.accepted - union}, phantom: {union - self.accepted}")
        assert sum(len(b) for b in buckets) == len(union), (
            "a request is in two lifecycle buckets at once")
        assert not (self.accepted & self.rejected)
        assert sched.queue_depth == len(queued)
        assert sched.num_active == len(active)
        assert sched.has_work == bool(queued or shed or active)


def _apply(sched, clk, model, code, a, b):
    if code == 0:                                          # submit
        req = _req(a % 4, DEADLINES[b % len(DEADLINES)])
        try:
            sched.submit(req)
            model.on_accept(req)
        except QueueFullError:
            model.rejected.add(req.rid)
    elif code == 1:                                        # admit
        placed = sched.admit()
        prios = [r.priority for _, r in placed]
        assert prios == sorted(prios, reverse=True), (
            f"admit round out of priority order: {prios}")
        for _, r in placed:
            last = model.last_admitted.get(r.priority)
            cur = model.submit_order[r.rid]
            assert last is None or cur > last, (
                f"FIFO violated within priority {r.priority}")
            model.last_admitted[r.priority] = cur
        if sched.num_active < sched.slots:
            assert sched.queue_depth == 0, (
                "admit left work queued despite free slots")
    elif code == 2:                                        # complete a slot
        occupied = sched.active_slots()
        if occupied:
            sched.complete(occupied[a % len(occupied)])
    elif code == 3:                                        # cancel queued
        q = sched.queue
        if q:
            r = sched.cancel(q[a % len(q)].rid)
            assert r is not None
            model.cancelled.add(r.rid)
        else:
            assert sched.cancel(10 ** 9) is None
    elif code == 4:                                        # pop_done
        for r in sched.pop_done():
            assert r.rid not in model.drained_done, "done delivered twice"
            model.drained_done.add(r.rid)
    elif code == 5:                                        # pop_shed
        for r in sched.pop_shed():
            assert r.rid not in model.drained_shed, "shed delivered twice"
            model.drained_shed.add(r.rid)
    elif code == 6:                                        # advance time
        clk.advance((a % 11) * 0.02)


def _run_ops(ops, slots=2, max_queue=4):
    clk = VirtualClock()
    sched = Scheduler(slots, max_queue=max_queue, clock=clk)
    model = _Model()
    for code, a, b in ops:
        _apply(sched, clk, model, code % N_OPCODES, a, b)
        model.check(sched)
    # settle: pump until empty — every accepted request must terminate in
    # exactly one of done/shed/cancelled
    for _ in range(10 * (len(ops) + 1)):
        if not sched.has_work:
            break
        sched.admit()
        for s in sched.active_slots():
            sched.complete(s)
        _apply(sched, clk, model, 4, 0, 0)
        _apply(sched, clk, model, 5, 0, 0)
        model.check(sched)
    assert not sched.has_work, "scheduler failed to drain"
    # pending done/shed lists deliberately don't count as has_work — one
    # final drain collects anything completed before the settle loop began
    _apply(sched, clk, model, 4, 0, 0)
    _apply(sched, clk, model, 5, 0, 0)
    model.check(sched)
    assert (model.drained_done | model.drained_shed
            | model.cancelled) == model.accepted


# ------------------------------------------------------- randomized driver
@pytest.mark.parametrize("seed", range(12))
def test_random_interleavings_preserve_lifecycle(seed):
    rng = np.random.default_rng(seed)
    ops = [(int(c), int(a), int(b))
           for c, a, b in zip(rng.integers(0, N_OPCODES, 150),
                              rng.integers(0, 11, 150),
                              rng.integers(0, 3, 150))]
    _run_ops(ops, slots=1 + seed % 3, max_queue=(None, 1, 4)[seed % 3])


@given(ops=st.lists(st.tuples(st.integers(0, N_OPCODES - 1),
                              st.integers(0, 10), st.integers(0, 2)),
                    max_size=80))
@settings(max_examples=60, deadline=None)
def test_hypothesis_interleavings_preserve_lifecycle(ops):
    _run_ops(ops)


# ----------------------------------------------------------- directed cases
def test_priority_then_fifo_admission_order():
    sched = Scheduler(4, clock=VirtualClock())
    rids = [sched.submit(_req(p, None)).rid for p in (0, 2, 1, 2, 0)]
    placed = [r.rid for _, r in sched.admit()]
    # priority 2 first (in submit order), then 1, then 0 (in submit order)
    assert placed[:4] == [rids[1], rids[3], rids[2], rids[0]]


def test_has_work_true_with_only_shed_pending():
    clk = VirtualClock()
    sched = Scheduler(1, clock=clk)
    sched.submit(_req(0, 0.01))
    clk.advance(1.0)
    assert sched.admit() == []              # expired: shed, not placed
    assert sched.queue_depth == 0 and sched.num_active == 0
    assert sched.has_work                   # pop_shed() still owed
    assert len(sched.pop_shed()) == 1
    assert not sched.has_work


def test_cancel_missing_rid_is_none_and_harmless():
    sched = Scheduler(1, clock=VirtualClock())
    r = sched.submit(_req(0, None))
    assert sched.cancel(r.rid + 1000) is None
    assert sched.queue_depth == 1
    assert sched.cancel(r.rid) is r
    assert sched.queue_depth == 0 and not sched.has_work
