"""Optional-hypothesis shim: property tests skip cleanly when it's absent.

The container may not ship ``hypothesis``; a bare import would abort pytest
collection for the whole module (and with ``-x``, the whole suite), taking the
plain unit tests down with it.  Importing ``given``/``settings``/``st`` from
here instead keeps unit tests running and turns each ``@given`` test into a
clean skip.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None, so module-level ``st.<x>(...)`` still runs."""

        def __getattr__(self, _name):
            return lambda *_a, **_k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn
