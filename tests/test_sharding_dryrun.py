"""Sharding rules + a small-mesh dry-run (subprocess: needs >1 host device).

The full production dry-run (512 devices, all 40 cells) runs via
``python -m repro.launch.dryrun --all``; here we assert the machinery on an
8-device toy mesh quickly enough for CI.
"""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import batch_spec, param_specs
from repro.models import api


def _specs_for(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: api.init_model(cfg, k),
                            jax.random.PRNGKey(0))
    return param_specs(params)


def test_dense_param_specs():
    specs = _specs_for("qwen2.5-32b")
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wq"]["b"] == P(None, "model")
    assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", None)
    assert specs["layers"]["ffn"]["w1"]["w"] == P(None, None, "model")
    assert specs["layers"]["ffn"]["w2"]["w"] == P(None, "model", None)
    assert specs["layers"]["ln1"]["scale"] == P(None, None)
    # scales follow their weight's out-channel sharding
    assert specs["layers"]["ffn"]["w1"]["s_w"] == P(None, None, "model")
    assert specs["layers"]["ffn"]["w2"]["s_w"] == P(None, None, None)


def test_moe_param_specs():
    specs = _specs_for("qwen2-moe-a2.7b")
    assert specs["layers"]["moe"]["w1"]["w"] == P(None, None, None, "model")
    assert specs["layers"]["moe"]["w2"]["w"] == P(None, None, "model", None)
    assert specs["layers"]["moe"]["router"] == P(None, None, None)


def test_ssm_param_specs():
    specs = _specs_for("zamba2-2.7b")
    assert specs["mamba"]["in_x"]["w"] == P(None, None, None, "model")
    assert specs["mamba"]["out_proj"]["w"] == P(None, None, "model", None)
    assert specs["mamba"]["in_bc"]["w"] == P(None, None, None, None)
    assert specs["shared"]["attn"]["wq"]["w"] == P(None, "model")


def test_batch_spec_axes():
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    assert batch_spec(mesh1, 2) == P("data", None)
    mesh2 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert batch_spec(mesh2, 2) == P(("pod", "data"), None)


SMALL_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch import dryrun
mesh = jax.make_mesh((2, 4), ("data", "model"))
results = {}
for arch, shape in [("stablelm-3b", "train_4k"), ("stablelm-3b", "decode_32k"),
                    ("granite-moe-3b-a800m", "train_4k")]:
    built, skip = dryrun._build_cell(arch, shape, mesh, policy_kind="mkq50",
                                     distill=False, grad_mode="mse",
                                     extra={"microbatch": 4})
    fn, specs = built
    with mesh:
        compiled = fn.lower(*specs).compile()
    txt = compiled.as_text()
    has_coll = any(op in txt for op in ("all-reduce", "all-gather",
                                        "reduce-scatter"))
    results[f"{arch}/{shape}"] = has_coll
print(json.dumps(results))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SMALL_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(results.values()), results  # SPMD collectives present


def test_dryrun_artifacts_schema():
    """The stored dry-run JSONs (deliverable e/g) carry every roofline field."""
    import glob
    paths = glob.glob("experiments/dryrun/*.json")
    if not paths:
        pytest.skip("no dry-run artifacts in this checkout")
    ok = skipped = 0
    meshes = set()
    for p in paths:
        with open(p) as f:
            r = json.load(f)
        meshes.add(r["mesh"])
        if r["status"] == "skipped":
            skipped += 1
            assert "full-attention" in r["reason"]
            continue
        ok += 1
        assert r["chips"] in (256, 512)
        for k in ("compute_s", "memory_s", "collective_s"):
            assert r["roofline_terms_s"][k] >= 0
        assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
        m = r["memory"]
        assert m["total_bytes"] == m["argument_bytes"] + m["temp_bytes"]
        assert r["hlo_analysis"]["flops"] > 0
    assert meshes == {"single", "multi"}
    assert ok >= 60 and skipped >= 16


def test_elastic_resume_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.launch.elastic import elastic_resume
    mgr = CheckpointManager(str(tmp_path))
    state = {"params": {"layers": {"ffn": {"w1": {
        "w": jax.numpy.ones((2, 4, 4)),
        "s_w": jax.numpy.ones((2, 1, 4)),
        "s_a": jax.numpy.ones((2,))}}}}}
    mgr.save(5, state)
    restored, step, mesh = elastic_resume(state, mgr, model_parallel=1)
    assert step == 5
    assert mesh.devices.size == len(jax.devices())
    w = restored["params"]["layers"]["ffn"]["w1"]["w"]
    assert w.shape == (2, 4, 4)
