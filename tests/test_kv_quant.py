"""Quantized KV cache tests (DESIGN.md §8).

Invariants:
* per-(token, head) quantize/dequantize round-trips within the grid's
  half-step error bound for both kv_bits;
* int4 nibble packing along head_dim is lossless over the code grid;
* the fused Pallas decode-attention kernel matches the dequantize-then-attend
  reference on the SAME quantized cache to float ulp, and the fp32 reference
  within the quantization error budget;
* slot isolation under refill holds EXACTLY with packed buffers — a request
  decoded in a recycled slot emits the tokens it emits on a fresh engine
  (mirrors test_serving_subsystem.py for the fp cache);
* kv_bits=8/4 engine token streams track the fp32-cache streams on the
  tier-1 model within the asserted agreement tolerance (int8 is empirically
  exact here; int4 is held to a looser floor);
* ServeMetrics percentile reporting survives sub-2-sample windows (the
  --quick bench path the CI gate runs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.kernels import ops
from repro.kernels.kv_pack import (dequantize_kv, kv_qmax, pack_nibbles_last,
                                   quantize_kv, unpack_nibbles_last)
from repro.models import api
from repro.models.attention import _repeat_kv, cached_decode_attention
from repro.serving import Request, ServeMetrics, ServingEngine

KEY = jax.random.PRNGKey(0)


def _engine(kv_bits, *, slots=2, policy="int4", max_len=64):
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    if policy == "fp32":
        pol, backend, fuse = None, "reference", False
    else:
        pol = QuantPolicy(num_layers=n, mode="int",
                          last_k_int4=n if policy == "int4" else 0)
        backend, fuse = "pallas", policy == "int4"
    plan = ExecutionPlan.build(cfg, pol, backend=backend, kv_bits=kv_bits,
                               fuse_epilogue=fuse)
    params = api.init_model(cfg, KEY)
    if pol is not None:
        params = deploy(params, plan).params
    return ServingEngine(params, plan, slots=slots, max_len=max_len), cfg


def _streams(eng, prompts, max_new=6):
    for p in prompts:
        eng.submit(Request(prompt=p.copy(), max_new_tokens=max_new))
    eng.run_until_drained()
    return {r.rid: r.out.tolist() for r in eng.done}


# ------------------------------------------------------------- round-trip

@pytest.mark.parametrize("bits", [8, 4])
def test_kv_roundtrip_error_bound(bits):
    """|x - dq(q(x))| <= scale/2 per element: rounding to the grid never
    loses more than half a step (scales are per-(token, head) amax / qmax,
    so nothing clips)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 4, 32)) * 3.0, jnp.float32)
    codes, scales = quantize_kv(x, bits)
    assert codes.dtype == (jnp.uint8 if bits == 4 else jnp.int8)
    assert codes.shape == (2, 16, 4, 32 // (2 if bits == 4 else 1))
    assert scales.shape == (2, 16, 4)
    dq = np.asarray(dequantize_kv(codes, scales))
    bound = np.broadcast_to(np.asarray(scales)[..., None] * 0.5 + 1e-7,
                            x.shape)
    np.testing.assert_array_less(np.abs(np.asarray(x) - dq), bound)
    # relative error shrinks with bits: amax/qmax halves the step per bit
    rel = np.abs(np.asarray(x) - dq).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.5 / kv_qmax(bits) + 1e-3


def test_kv_zero_rows_quantize_to_zero():
    """All-zero rows (cache padding) must survive exactly: eps-floored scale,
    zero codes, zero dequant."""
    codes, scales = quantize_kv(jnp.zeros((3, 4, 8)), 4)
    np.testing.assert_array_equal(np.asarray(dequantize_kv(codes, scales)),
                                  np.zeros((3, 4, 8), np.float32))


def test_pack_nibbles_last_roundtrip():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-7, 9, size=(5, 3, 16)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles_last(pack_nibbles_last(codes))),
        np.asarray(codes))


# ------------------------------------------------- fused decode attention

@pytest.mark.parametrize("bits", [8, 4])
def test_decode_attention_kernel_matches_reference(bits):
    """The Pallas kernel (in-VMEM dequant + online softmax + fp new-token
    fold-in) must match the jnp dequantize-then-attend reference on the SAME
    packed cache near-exactly, and the full-precision cache within the
    quantization error budget."""
    rng = np.random.default_rng(0)
    B, S, H, Hkv, dh = 3, 64, 8, 4, 16
    G = H // Hkv
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((B, 1, Hkv, dh)), jnp.float32)
    lens = jnp.asarray([5, 37, 64], jnp.int32)   # per-slot cursors

    kq, ks = quantize_kv(k, bits)
    vq, vs = quantize_kv(v, bits)
    out = np.asarray(ops.decode_attention(q[:, 0], kq, vq, ks, vs,
                                          kn[:, 0], vn[:, 0], lens))
    ref = np.asarray(cached_decode_attention(
        q, _repeat_kv(dequantize_kv(kq, ks), G),
        _repeat_kv(dequantize_kv(vq, vs), G),
        _repeat_kv(kn, G), _repeat_kv(vn, G), lens)[:, 0])
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-5)

    fp = np.asarray(cached_decode_attention(
        q, _repeat_kv(k, G), _repeat_kv(v, G),
        _repeat_kv(kn, G), _repeat_kv(vn, G), lens)[:, 0])
    tol = {8: 0.02, 4: 0.35}[bits]
    np.testing.assert_allclose(out, fp, rtol=0, atol=tol)


def test_decode_attention_respects_per_slot_length():
    """Rows at positions >= a slot's cursor must contribute nothing: poisoning
    them cannot change the output (the slot-isolation property at the kernel
    level)."""
    rng = np.random.default_rng(2)
    B, S, Hkv, dh = 2, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 2 * Hkv, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((B, Hkv, dh)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((B, Hkv, dh)), jnp.float32)
    lens = jnp.asarray([4, 9], jnp.int32)
    kq, ks = quantize_kv(k, 4)
    vq, vs = quantize_kv(v, 4)
    out = np.asarray(ops.decode_attention(q, kq, vq, ks, vs, kn, vn, lens))

    # poison every row past the cursor with large codes and scales
    mask = (np.arange(S)[None, :, None] >= np.asarray(lens)[:, None, None])
    kq2 = jnp.where(jnp.asarray(mask)[..., None], jnp.uint8(0xFF), kq)
    vq2 = jnp.where(jnp.asarray(mask)[..., None], jnp.uint8(0xFF), vq)
    ks2 = jnp.where(jnp.asarray(mask), 1e4, ks)
    vs2 = jnp.where(jnp.asarray(mask), 1e4, vs)
    out2 = np.asarray(ops.decode_attention(q, kq2, vq2, ks2, vs2,
                                           kn, vn, lens))
    np.testing.assert_array_equal(out, out2)


# ------------------------------------------------------- engine end-to-end

@pytest.mark.parametrize("kv_bits", [8, 4])
def test_quantized_cache_slot_isolation_across_refills(kv_bits):
    """A request decoded in a recycled slot must produce exactly the tokens
    it produces on a fresh engine — with PACKED buffers the reset must zero
    codes AND scales, and per-token scales must never alias across refills."""
    r1 = np.arange(1, 11, dtype=np.int32)
    r2 = np.array([7, 3, 11, 2], np.int32)

    eng, _ = _engine(kv_bits, slots=1)
    assert eng.kv.quantized and eng.kv.kv_bits == kv_bits
    recycled = _streams(eng, [r1, r2])[1]

    fresh_eng, _ = _engine(kv_bits, slots=1)
    fresh = _streams(fresh_eng, [r2])[0]
    np.testing.assert_array_equal(recycled, fresh)


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_quantized_concurrent_slots_match_solo_runs(kv_bits):
    prompts = [np.array([5, 9, 2], np.int32),
               np.array([8, 8, 1, 4, 12], np.int32)]
    eng, _ = _engine(kv_bits, slots=2)
    batched = _streams(eng, prompts, max_new=5)
    for i, p in enumerate(prompts):
        solo, _ = _engine(kv_bits, slots=2)
        np.testing.assert_array_equal(batched[i],
                                      _streams(solo, [p], max_new=5)[0])


def test_kv_bits_token_streams_track_fp32():
    """Acceptance: kv_bits=8/4 decode streams match the fp32-cache stream on
    the tier-1 model within tolerance. With fp32 weights isolating the KV
    effect, int8 KV is empirically EXACT on this model and asserted so;
    int4 KV is held to >= 60% token agreement with an exact first token
    (prefill runs at full precision and quantizes on insert)."""
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8, 2, 8], np.int32),
               np.array([9, 9, 9], np.int32)]
    outs = {}
    for kv_bits in (16, 8, 4):
        eng, _ = _engine(kv_bits, policy="fp32")
        outs[kv_bits] = _streams(eng, prompts, max_new=8)

    assert outs[8] == outs[16]
    toks16 = [t for rid in outs[16] for t in outs[16][rid]]
    toks4 = [t for rid in outs[4] for t in outs[4][rid]]
    agree = np.mean([a == b for a, b in zip(toks16, toks4)])
    assert agree >= 0.6, f"int4 KV stream agreement {agree:.2f}"
    for rid in outs[16]:   # first token comes out of the fp prefill pass
        assert outs[4][rid][0] == outs[16][rid][0]


def test_pallas_decode_attention_matches_jnp_path_end_to_end():
    """QuantPolicy-selected kernel vs the dequantize reference: deployed int8
    weights with use_pallas on/off must emit the same tokens for the same
    kv_bits (the integer matmuls are exact; decode attention differs only in
    fp32 summation order)."""
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8], np.int32)]
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=0)
    streams = []
    for backend in ("reference", "pallas"):
        plan = ExecutionPlan.build(cfg, pol, backend=backend, kv_bits=8)
        model = deploy(api.init_model(cfg, KEY), plan)
        eng = ServingEngine(model, slots=2, max_len=64)
        streams.append(_streams(eng, prompts, max_new=5))
    assert streams[0] == streams[1]


def test_token_mode_rejects_quantized_kv():
    """Token-mode prefill keeps the fp decode state; a quantized cache there
    would silently take the legacy static-scale path — the plan build
    rejects the combination up front."""
    cfg = reduced(get_config("stablelm-3b"))
    with pytest.raises(ValueError, match="kv_bits"):
        ExecutionPlan.build(cfg, None, prefill_mode="token", kv_bits=8)


# ------------------------------------------------------------------ metrics

def test_metrics_single_sample_percentiles():
    """Sub-2-sample windows (tiny --quick bench runs) must not crash the
    summary: the lone sample is every percentile."""
    m = ServeMetrics()
    m.record("decode", 0.004, 1)
    s = m.summary()
    assert s["decode_steps"] == 1
    assert s["decode_p50_ms"] == pytest.approx(4.0)
    assert s["decode_p99_ms"] == pytest.approx(4.0)
    assert "prefill_p50_ms" not in s          # zero-sample kind stays absent
    assert m.report()                          # renders without crashing


def test_metrics_empty_summary():
    s = ServeMetrics().summary()
    assert s["total_tokens"] == 0
    assert s["tokens_per_s"] == 0.0
