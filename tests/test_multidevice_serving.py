"""Tensor-parallel serving on a forced multi-device host (DESIGN.md §16).

NOT part of the default suite: tests/conftest.py deliberately sets no XLA
device-count flags (the tier-1 run must see the host as-is), so this module
only runs when the caller opted in:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        REPRO_MULTIDEVICE=1 PYTHONPATH=src \\
        python -m pytest tests/test_multidevice_serving.py -q

The correctness bar is BYTE-IDENTITY: a plan built at tp=N must emit the
same token streams as tp=1 for every (w_bits, kv_bits, kv_paging) cell —
int32 matmul accumulation makes the row-parallel psums exact, and the
sampler inputs (embed / lm_head) stay replicated so the fp reduction order
matches the single-device run.
"""
import os

import pytest

if os.environ.get("REPRO_MULTIDEVICE") != "1":          # noqa: E402 — the
    # guard must run before jax initializes the platform
    pytest.skip("set REPRO_MULTIDEVICE=1 (with XLA_FLAGS="
                "--xla_force_host_platform_device_count=8) to run",
                allow_module_level=True)

import jax  # noqa: E402

if jax.device_count() < 4:
    pytest.skip(f"needs >= 4 XLA devices, host has {jax.device_count()} "
                "(export XLA_FLAGS=--xla_force_host_platform_device_count=8)",
                allow_module_level=True)

import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core.policy import QuantPolicy  # noqa: E402
from repro.deploy import (DeployedModel, ExecutionPlan,  # noqa: E402
                          deploy)
from repro.launch.mesh import make_mesh_for_devices, make_tp_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.serving import GenerationRequest, ServingEngine  # noqa: E402

pytestmark = pytest.mark.multidevice

KEY = jax.random.PRNGKey(0)


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


def _prompts(vocab, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, int(rng.integers(3, 7))).astype(np.int32)
            for _ in range(n)]


def _serve(model, prompts, *, slots=2, max_len=32):
    eng = ServingEngine(model, slots=slots, max_len=max_len)
    streams = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
               for p in prompts]
    eng.run_until_drained()
    return [tuple(s.result().tokens) for s in streams]


def _save(model, path):
    return model.save(str(path))


# ------------------------------------------------------- byte-identity grid
@pytest.mark.parametrize("last_k_int4", [0, None],
                         ids=["int8", "int4"])
@pytest.mark.parametrize("kv_bits", [16, 8, 4],
                         ids=["kv16", "kv8", "kv4"])
@pytest.mark.parametrize("kv_paging", ["dense", "paged"])
def test_tp_streams_byte_identical(tmp_path, last_k_int4, kv_bits,
                                   kv_paging):
    cfg = _cfg()
    k = cfg.num_layers if last_k_int4 is None else last_k_int4
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int", last_k_int4=k)
    plan = ExecutionPlan.build(cfg, pol, backend="reference",
                               kv_bits=kv_bits, kv_paging=kv_paging)
    model = deploy(api.init_model(cfg, KEY), plan)
    prompts = _prompts(cfg.vocab_size)
    ref = _serve(model, prompts)
    path = _save(model, tmp_path / "art")
    for tp in (2, 4):
        sharded = DeployedModel.load(path, tp=tp)    # tp=1 -> N reshard
        assert sharded.plan.tp == tp
        got = _serve(sharded, prompts)
        assert got == ref, (f"tp={tp} diverged from tp=1 "
                            f"({last_k_int4=}, {kv_bits=}, {kv_paging=})")


def test_artifact_saved_sharded_reshards_both_ways(tmp_path):
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend="reference", kv_bits=8,
                               tp=2)
    model = deploy(api.init_model(cfg, KEY), plan)
    prompts = _prompts(cfg.vocab_size, seed=1)
    ref = _serve(model, prompts)
    path = _save(model, tmp_path / "tp2")       # saved WITH tp=2 layout

    as_saved = DeployedModel.load(path)          # layout from metadata
    assert as_saved.plan.tp == 2
    assert _serve(as_saved, prompts) == ref

    for tp in (1, 4):                            # reshard on load, both ways
        re = DeployedModel.load(path, tp=tp)
        assert re.plan.tp == tp
        assert _serve(re, prompts) == ref


def test_sharded_params_actually_span_devices():
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend="reference", tp=4)
    model = deploy(api.init_model(cfg, KEY), plan)
    wq = model.params["layers"][0]["attn"]["wq"]["wq"]
    assert len(wq.sharding.device_set) == 4
    # sampler inputs stay replicated (byte-identity contract)
    assert len(model.params["embed"].sharding.device_set) == 4
    assert model.params["embed"].sharding.is_fully_replicated


def test_warmup_composes_with_tp():
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend="reference", kv_bits=8,
                               tp=2)
    model = deploy(api.init_model(cfg, KEY), plan)
    eng = ServingEngine(model, slots=2, max_len=32, warmup=True)
    assert set(eng._prefill_fns) == {(8, 1), (16, 1), (32, 1)}
    prompts = _prompts(cfg.vocab_size, n=2, seed=2)
    streams = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
               for p in prompts]
    eng.run_until_drained()
    assert all(len(s.result().tokens) == 4 for s in streams)


# ------------------------------------------------------------ mesh metadata
def test_mesh_layout_on_eight_devices():
    layout = make_mesh_for_devices(8, 4)
    assert layout.shape == (2, 4)
    assert not layout.degraded
    assert layout.requested_model == 4

    degraded = make_mesh_for_devices(8, 3, allow_degrade=True)
    assert degraded.degraded
    assert degraded.requested_model == 3
    assert degraded.shape[1] == 1           # halved 3 -> 1 (the old silent
    #                                         behavior, now labeled)

    mesh = make_tp_mesh(4)
    assert mesh.shape["model"] == 4
