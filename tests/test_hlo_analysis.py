"""Unit tests for the HLO roofline analyzer (launch/hlo_analysis.py).

These pin the trip-count and slice-aware accounting semantics on handcrafted
HLO text, so analyzer regressions can't silently skew the roofline tables.
"""
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations

SIMPLE = """
HloModule test

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%i, %d)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128,128]{1,0}) tuple(%c0, %a)
  %wh = (s32[], f32[128,128]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_while_trip_count_multiplies_dot_flops():
    h = analyze(SIMPLE)
    # 2 * 128^3 per dot * 7 trips
    assert h["flops"] == pytest.approx(7 * 2 * 128 ** 3)
    assert h["int_flops"] == 0


COLLECTIVE = """
HloModule test

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%a), replica_groups={}, to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%ar), dimensions={0}
  ROOT %o = f32[64,64]{1,0} copy(%ag)
}
"""


def test_collective_bytes_and_ar_factor():
    h = analyze(COLLECTIVE)
    sz = 64 * 64 * 4
    assert h["collective_bytes"]["all-reduce"] == 2 * sz   # reduce+broadcast
    assert h["collective_bytes"]["all-gather"] == sz
    assert h["collective_bytes_total"] == 3 * sz


SLICED = """
HloModule test

ENTRY %main (stack: f32[10,64,64], idx: s32[]) -> f32[64,64] {
  %stack = f32[10,64,64]{2,1,0} parameter(0)
  %idx = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %sl = f32[1,64,64]{2,1,0} dynamic-slice(%stack, %idx, %z, %z), dynamic_slice_sizes={1,64,64}
}
"""


def test_dynamic_slice_charges_slice_not_buffer():
    h = analyze(SLICED)
    # 2 * slice bytes, NOT 10x the stack
    assert h["hbm_bytes"] == 2 * 64 * 64 * 4


def test_parse_computations_names():
    comps = parse_computations(SIMPLE)
    assert "body" in comps and "cond" in comps and "main" in comps
    opcodes = {op.opcode for op in comps["body"].ops}
    assert "dot" in opcodes


def test_int_dot_classified():
    hlo = """
HloModule t

ENTRY %main (a: s8[32,32], b: s8[32,32]) -> s32[32,32] {
  %a = s8[32,32]{1,0} parameter(0)
  %b = s8[32,32]{1,0} parameter(1)
  ROOT %d = s32[32,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    h = analyze(hlo)
    assert h["int_flops"] == 2 * 32 ** 3
    assert h["float_flops"] == 0
