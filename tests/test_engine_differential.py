"""Differential test: batched/interleaved serving == serial serving.

The engine's contract (api.py §sampling, DESIGN.md §10) is that a request's
token stream is a pure function of (prompt, sampling seed) — never of batch
composition, admission order, or what else got cancelled around it. This
test drives random interleavings of submit / cancel / engine_step over a
mix of sampling configurations and checks every request that ran to
completion against a serial run of the same request on an otherwise idle
engine: byte-identical tokens, identical finish_reason.

Runs against both quantized weight plans (int8 and int4+fused pallas) — the
paths where a batching bug would also change numerics.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.serving import GenerationRequest, SamplingParams, ServingEngine

KEY = jax.random.PRNGKey(0)
_CACHE = {}


def _deployed(mode):
    """(params, plan) per weight mode, cached across tests in this module."""
    if mode not in _CACHE:
        cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
        n = cfg.num_layers
        pol = QuantPolicy(num_layers=n, mode="int",
                          last_k_int4=n if mode == "int4" else 0)
        plan = ExecutionPlan.build(cfg, pol, backend="pallas",
                                   fuse_epilogue=(mode == "int4"),
                                   kv_bits=4 if mode == "int4" else 16)
        params = deploy(api.init_model(cfg, KEY), plan).params
        _CACHE[mode] = (params, plan, cfg)
    return _CACHE[mode]


def _specs(cfg, rng, n):
    """n request specs cycling through the sampling configurations."""
    out = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              int(rng.integers(3, 11))).astype(np.int32)
        sampling = (None,      # greedy (plan default)
                    SamplingParams(temperature=0.8, top_k=5, seed=100 + i),
                    SamplingParams(temperature=1.2, top_p=0.9, seed=200 + i),
                    SamplingParams(temperature=0.7, top_k=8, top_p=0.8,
                                   seed=300 + i))[i % 4]
        out.append(dict(prompt=prompt,
                        max_new_tokens=int(rng.integers(2, 7)),
                        sampling=sampling,
                        stop_tokens=(frozenset({int(prompt[0]) % 7 + 1})
                                     if i % 5 == 0 else frozenset())))
    return out


def _fresh(spec):
    return GenerationRequest(prompt=spec["prompt"].copy(),
                             max_new_tokens=spec["max_new_tokens"],
                             sampling=spec["sampling"],
                             stop_tokens=spec["stop_tokens"])


def _interleaved(params, plan, specs, seed):
    """Random submit/cancel/step interleaving; returns {spec index:
    (tokens, finish_reason)} for every request."""
    eng = ServingEngine(params, plan, slots=2, max_len=64)
    rng = np.random.default_rng(seed)
    streams, done, cancelled = {}, {}, set()
    by_rid = {}
    next_i = 0
    for _ in range(10_000):
        if next_i >= len(specs) and not eng.scheduler.has_work:
            break
        op = int(rng.integers(0, 4))
        if op == 0 and next_i < len(specs):
            st = eng.submit(_fresh(specs[next_i]))
            streams[next_i] = st
            by_rid[st.rid] = next_i
            next_i += 1
        elif op == 1 and len(cancelled) < len(specs) // 3:
            live = [i for i, st in streams.items()
                    if i not in cancelled and i not in done]
            if live:
                i = live[int(rng.integers(len(live)))]
                streams[i].cancel()
                cancelled.add(i)
        elif eng.scheduler.has_work:
            eng.engine_step()
            for req in eng.pop_done():
                i = by_rid[req.rid]
                done[i] = (np.asarray(req.out).tolist(), req.finish_reason)
    else:
        pytest.fail("interleaved run did not drain")
    for req in eng.pop_done():
        done[by_rid[req.rid]] = (np.asarray(req.out).tolist(),
                                 req.finish_reason)
    assert set(done) == set(range(next_i)) == set(range(len(specs)))
    return done


def _serial(eng, spec):
    """Run one request alone to completion on an idle engine."""
    res = eng.submit(_fresh(spec)).result()
    eng.pop_done()
    return np.asarray(res.tokens).tolist(), res.finish_reason


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_interleaved_streams_match_serial(mode):
    params, plan, cfg = _deployed(mode)
    rng = np.random.default_rng(42)
    specs = _specs(cfg, rng, 8)
    done = _interleaved(params, plan, specs, seed=7)
    serial_eng = ServingEngine(params, plan, slots=2, max_len=64)
    n_compared = 0
    for i, spec in enumerate(specs):
        tokens, reason = done[i]
        if reason == "cancelled":
            # a cancelled stream must still be a PREFIX of the serial run
            ref_tokens, _ = _serial(serial_eng, spec)
            assert tokens == ref_tokens[:len(tokens)], (
                f"request {i}: cancelled stream diverged before the cut")
            continue
        ref_tokens, ref_reason = _serial(serial_eng, spec)
        assert reason == ref_reason, f"request {i}: finish_reason differs"
        assert tokens == ref_tokens, (
            f"request {i} ({mode}): interleaved {tokens} != "
            f"serial {ref_tokens}")
        n_compared += 1
    assert n_compared >= len(specs) // 2, "too few requests ran to completion"


def test_interleaving_order_is_irrelevant_int8():
    """Two DIFFERENT interleavings of the same spec set complete with
    identical per-request streams (cancel disabled so every request
    finishes in both runs)."""
    params, plan, cfg = _deployed("int8")
    specs = _specs(cfg, np.random.default_rng(1), 6)
    for s in specs:
        s["stop_tokens"] = frozenset()      # keep lengths comparable
    a = _interleaved(params, plan, [dict(s, max_new_tokens=s["max_new_tokens"])
                                    for s in specs], seed=11)
    b = _interleaved(params, plan, specs, seed=99)
    # seeds 11/99 produce different submit/step orders; cancels may differ —
    # compare only requests completed in both
    both = [i for i in a if a[i][1] != "cancelled" and b[i][1] != "cancelled"]
    assert len(both) >= 3
    for i in both:
        assert a[i] == b[i], f"request {i}: stream depends on interleaving"
