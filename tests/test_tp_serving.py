"""Tensor-parallel plan axis, mesh metadata, serving shard rules, engine
warmup and the cheap autosearch probe (DESIGN.md §16/§13).

Everything here runs on a single device: plan validation, spec resolution
and the MeshLayout metadata never build a multi-device mesh (that is the
point — a sharded plan must be constructible anywhere). The actual
multi-device byte-identity runs live in tests/test_multidevice_serving.py
behind REPRO_MULTIDEVICE=1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.deploy.plan import plan_from_meta, plan_to_meta
from repro.launch.mesh import make_mesh_for_devices, make_tp_mesh
from repro.models import api


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


def _int4_policy(cfg):
    return QuantPolicy(num_layers=cfg.num_layers, mode="int",
                       last_k_int4=cfg.num_layers)


# --------------------------------------------------------- mesh metadata
class TestMeshLayout:
    def test_auto_single_device(self):
        layout = make_mesh_for_devices(1)
        assert layout.shape == (1, 1)
        assert layout.requested_model == 0
        assert not layout.degraded
        assert layout.mesh.axis_names == ("data", "model")

    def test_explicit_non_divisor_raises(self):
        # the old behavior silently halved 4 -> 2 on 6 devices; now the
        # mismatch is an error naming both numbers (no mesh is built, so
        # this asserts fine on a 1-device host)
        with pytest.raises(ValueError, match="does not divide"):
            make_mesh_for_devices(6, 4)

    def test_bad_count_raises(self):
        with pytest.raises(ValueError, match="n_devices"):
            make_mesh_for_devices(0)

    def test_tp_mesh_needs_devices(self):
        need = jax.device_count() + 1
        with pytest.raises(RuntimeError, match="host has"):
            make_tp_mesh(need)

    def test_tp_mesh_single(self):
        mesh = make_tp_mesh(1)
        assert mesh.axis_names == ("model",)
        assert mesh.shape["model"] == 1


# ------------------------------------------------------ plan's tp axis
class TestPlanTp:
    def test_default_tp_is_one(self):
        plan = ExecutionPlan.build(_cfg(), _int4_policy(_cfg()))
        assert plan.tp == 1
        assert plan.make_mesh() is None

    def test_build_kwargs_round_trip(self):
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg), tp=2)
        assert plan.tp == 2
        assert plan.build_kwargs()["tp"] == 2
        again = ExecutionPlan.build(cfg, plan.policy, **plan.build_kwargs())
        assert again.tp == 2
        assert "tp=2" in plan.describe()

    def test_meta_round_trip_and_old_artifacts(self):
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg), tp=4)
        meta = plan_to_meta(plan)
        assert plan_from_meta(meta).tp == 4
        # an artifact written before the tp axis existed has no "tp" key
        # and must load as the single-device layout
        old = {**meta, "build": {k: v for k, v in meta["build"].items()
                                 if k != "tp"}}
        assert plan_from_meta(old).tp == 1

    def test_pallas_backend_rejected(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="single-device"):
            ExecutionPlan.build(cfg, _int4_policy(cfg), backend="pallas",
                                tp=2)

    def test_fp_policy_rejected(self):
        with pytest.raises(ValueError, match="mode='int'"):
            ExecutionPlan.build(_cfg(), None, tp=2)

    def test_act_bits_zero_rejected(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="act_bits=0"):
            ExecutionPlan.build(cfg, _int4_policy(cfg), act_bits=0, tp=2)

    def test_head_divisibility(self):
        cfg = _cfg()   # 4 heads: tp=3 cannot split them
        with pytest.raises(ValueError, match="num_heads"):
            ExecutionPlan.build(cfg, _int4_policy(cfg), tp=3)

    def test_int4_packed_rows_divisibility(self):
        # d_ff=26 divides tp=2 but NOT 2*tp=4: int4 codes shard their
        # packed K/2 nibble-pair rows, so the int4 build must refuse where
        # the int8 build (no packing) sails through
        cfg = _cfg().replace(d_ff=26)
        pol = _int4_policy(cfg)
        with pytest.raises(ValueError, match="2\\*tp"):
            ExecutionPlan.build(cfg, pol, tp=2)
        int8 = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                           last_k_int4=0)
        assert ExecutionPlan.build(cfg, int8, tp=2).tp == 2

    def test_token_only_family_rejected(self):
        cfg = reduced(get_config("xlstm-1.3b"))
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=0)
        with pytest.raises(ValueError, match="family"):
            ExecutionPlan.build(cfg, pol, prefill_mode="token", tp=2)

    def test_tp_mesh_lazy_until_placement(self):
        # the plan builds on any host; the device check fires at placement
        cfg = _cfg()
        need = jax.device_count() * 4   # guaranteed more than available
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg), tp=need)
        with pytest.raises(RuntimeError, match="host has"):
            plan.make_mesh()


# --------------------------------------------------- serving shard rules
class TestServingSpecs:
    def test_param_specs(self):
        from repro.distributed.sharding import serving_param_specs
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg))
        params = deploy(api.init_model(cfg, jax.random.PRNGKey(0)),
                        plan).params
        specs = serving_param_specs(params)
        # sampler inputs replicated (byte-identity rule), stacks sharded
        assert specs["embed"] == P(None, None)
        assert specs["lm_head"] == P(None, None)
        attn = specs["layers"][0]["attn"]
        for w in ("wq", "wk", "wv"):                  # column-parallel
            assert attn[w]["wq"][-1] == "model"
            assert attn[w]["s_w"][-1] == "model"      # scales follow out dim
        assert attn["wo"]["wq"][-2] == "model"        # row-parallel packed K
        ffn = specs["layers"][0]["ffn"]
        assert ffn["w1"]["wq"][-1] == "model"
        assert ffn["w1"]["b"][-1] == "model"          # bias rides the shard
        assert ffn["w2"]["wq"][-2] == "model"
        assert ffn["w2"]["s_w"][-1] is None           # row-parallel scale:
        #                                               N axis stays intact
        assert attn["wo"]["s_a"] == P(None)           # act scales replicated

    def test_state_specs(self):
        from repro.distributed.sharding import serving_state_specs
        mesh = make_tp_mesh(1)
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg), kv_bits=4)
        state = plan.decode_state(2, 32, per_slot_len=True)
        specs = serving_state_specs(state, mesh)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        by_base = {"/".join(str(getattr(p, "key", p)) for p in path)
                   .rsplit("/", 1)[-1]: spec for path, spec in flat}
        assert by_base["k_q"][-2] == "model"       # quantized KV heads
        assert by_base["k_scale"][-1] == "model"   # per-(token, head) scale
        assert all(a is None for a in by_base["len"])


# --------------------------------------------------------------- warmup
class TestWarmup:
    def test_prewarm_populates_compile_keys(self):
        from repro.serving import ServingEngine
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg), kv_bits=8)
        model = deploy(api.init_model(cfg, jax.random.PRNGKey(0)), plan)
        eng = ServingEngine(model, slots=2, max_len=32, warmup=True)
        # bucket ladder 8/16/32 at n=1 (prefill_batch=1), all compiled
        assert set(eng._prefill_fns) == {(8, 1), (16, 1), (32, 1)}
        # warmup itself records nothing
        assert "prefill_steps" not in eng.metrics.summary()

    def test_first_vs_steady_metrics(self):
        from repro.serving import GenerationRequest, ServingEngine
        cfg = _cfg()
        plan = ExecutionPlan.build(cfg, _int4_policy(cfg))
        model = deploy(api.init_model(cfg, jax.random.PRNGKey(0)), plan)
        eng = ServingEngine(model, slots=2, max_len=32)
        rng = np.random.default_rng(0)
        eng.submit(GenerationRequest(
            prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=4))
        eng.run_until_drained()
        s = eng.metrics.summary()
        assert s["decode_first_ms"] > 0
        # 3 decode steps: steady excludes the lifetime-first sample
        assert "decode_steady_p50_ms" in s
        assert s["prefill_first_ms"] > 0
        # lifetime-first survives the pop_summary drain
        eng.metrics.pop_summary()
        assert eng.metrics.summary()["decode_first_ms"] == s["decode_first_ms"]


# ------------------------------------------------- cheap autosearch probe
class TestCachedProbe:
    def test_probe_matches_full_deploy_exactly(self):
        from repro.core.autosearch import cached_probe_scorer
        from repro.data.synthetic import SyntheticClassification
        from repro.models.bert import (bert_classify_logits,
                                       init_bert_classifier, tinybert_config)

        cfg = tinybert_config(layers=3, d=64, heads=4, d_ff=128, vocab=256,
                              name="tinybert-probe")
        data = SyntheticClassification(cfg.vocab_size, 12, 16,
                                       num_classes=2, seed=0)
        params = init_bert_classifier(cfg, 2, jax.random.PRNGKey(0))
        calib = [data.batch(100 + i) for i in range(2)]
        n_deploys = [0]

        def deploy_policy(pol):
            n_deploys[0] += 1
            plan = ExecutionPlan.build(cfg, pol, backend="reference")
            return deploy(params, plan, calib)

        def score(model):
            correct = total = 0
            for i in range(3):
                b = data.batch(10_000 + i)
                logits, _ = bert_classify_logits(
                    model.params, model.plan, jnp.asarray(b["tokens"]))
                pred = np.asarray(jnp.argmax(logits, -1))
                correct += int((pred == b["labels"]).sum())
                total += len(pred)
            return correct / total

        cheap = cached_probe_scorer(deploy_policy, score)

        def mk(int4):
            return QuantPolicy(num_layers=cfg.num_layers, mode="int",
                               int4_layers=tuple(int4))

        # exhaustive: every subset of layers scores EXACTLY like the full
        # re-deploy path (the assembled slices are the same packed bytes)
        cheap_scores = {}
        for mask in range(2 ** cfg.num_layers):
            ls = tuple(l for l in range(cfg.num_layers) if mask >> l & 1)
            cheap_scores[ls] = cheap(mk(ls))
        # the cheap pass deployed exactly the two uniform grids
        assert n_deploys[0] == 2
        for ls, got in cheap_scores.items():
            assert got == score(deploy_policy(mk(ls))), ls

    def test_probe_memoizes(self):
        from repro.core.autosearch import cached_probe_scorer
        calls = [0]

        @dataclasses.dataclass
        class Fake:
            plan: object
            params: dict

        def fake_deploy(pol):
            raise AssertionError("fallback path must not deploy")

        # non-'layers' tree triggers the fallback; use a real tiny model
        # instead to confirm the memo: same policy scored twice = 1 eval
        from repro.data.synthetic import SyntheticClassification
        from repro.models.bert import init_bert_classifier, tinybert_config
        cfg = tinybert_config(layers=2, d=64, heads=4, d_ff=128, vocab=256,
                              name="tinybert-memo")
        params = init_bert_classifier(cfg, 2, jax.random.PRNGKey(1))

        def deploy_policy(pol):
            plan = ExecutionPlan.build(cfg, pol, backend="reference")
            return deploy(params, plan)

        def score(model):
            calls[0] += 1
            return float(len(model.plan.segments))

        cheap = cached_probe_scorer(deploy_policy, score)
        pol = QuantPolicy(num_layers=2, mode="int", int4_layers=(0,))
        a, b = cheap(pol), cheap(pol)
        assert a == b and calls[0] == 1
