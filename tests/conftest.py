# NOTE: no XLA device-count flags here — smoke tests and benches must see the
# real (single-CPU) device. Only launch/dryrun.py forces 512 placeholders.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
