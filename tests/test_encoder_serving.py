"""Encoder serving tests (DESIGN.md §14).

The encoder path's contract is the PR-5 exactness property transplanted to
bidirectional models: an EncodeRequest's result is a pure function of its
tokens — never of bucket padding, batch composition, or what other traffic
shares the engine. The headline tests assert BYTE-identical results between
the engine's batched bucketed forward and a direct single-row
``bert_classify_logits``/``bert_encode`` call at the exact length, for both
an int8 and an int4 W4A4 deployed plan (the quantized paths where a
batching bug would also change numerics).

Lifecycle tests reuse the generation-side semantics the encode path shares:
deadline shedding and cancellation through the same scheduler, on a
``VirtualClock`` so timing is exact. The decode-engine ``score`` task
(prompt log-likelihood through the chunked prefill path) gets the same
batch-independence treatment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.models.bert import (bert_classify_logits, bert_encode, bert_pool,
                               init_bert_classifier, tinybert_config)
from repro.serving import (EncodeRequest, GenerationRequest, ServingEngine,
                           VirtualClock)

KEY = jax.random.PRNGKey(0)
_CACHE = {}


def _encoder_model(mode):
    """Deployed TinyBERT classifier under a mode='encoder' plan, cached."""
    if mode not in _CACHE:
        cfg = tinybert_config(num_classes=2, layers=2, d=64, heads=4,
                              d_ff=128, vocab=256, name="tinybert-test")
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=cfg.num_layers if mode == "int4" else 0)
        plan = ExecutionPlan.build(
            cfg, pol, backend="reference", mode="encoder", prefill_batch=4,
            **({"act_bits": 4} if mode == "int4" else {}))
        _CACHE[mode] = deploy(init_bert_classifier(cfg, 2, KEY), plan)
    return _CACHE[mode]


def _decoder_model():
    if "decoder" not in _CACHE:
        cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
        pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                          last_k_int4=cfg.num_layers)
        plan = ExecutionPlan.build(cfg, pol, backend="reference", act_bits=4)
        _CACHE["decoder"] = (deploy(api.init_model(cfg, KEY), plan), cfg)
    return _CACHE["decoder"]


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, n).astype(np.int32) for n in lens]


# --------------------------------------------- batched == direct, bitwise
def _direct(model, prompts, bucket, task):
    """The reference the engine must be byte-faithful to: ONE jitted
    ``bert_classify_logits``/``bert_encode`` call on the same padded batch
    the engine's group runs (public API, no engine machinery)."""
    toks = np.zeros((len(prompts), bucket), np.int32)
    lens = np.array([len(p) for p in prompts], np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p

    @jax.jit
    def fwd(params, toks, lens):
        h, _ = bert_encode(params, model.plan, toks, lengths=lens)
        embed = bert_pool(params, h)
        logits = (embed @ params["classifier"]["w"]
                  + params["classifier"]["b"])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return {"classify": logits, "embed": embed, "score": logp[:, 1]}

    return np.asarray(fwd(model.params, jnp.asarray(toks),
                          jnp.asarray(lens))[task])


@pytest.mark.parametrize("mode", ["int8", "int4"])
@pytest.mark.parametrize("task", ["classify", "embed", "score"])
def test_engine_batched_matches_direct_forward(mode, task):
    """One mixed-length group through the engine == the direct batched
    forward, byte-for-byte (int8 AND int4 plans) — the engine's grouping,
    bucketing, row routing and result slicing add nothing numerically."""
    model = _encoder_model(mode)
    eng = ServingEngine(model, slots=4, max_len=64, clock=VirtualClock())
    # lengths 5..8 share one bucket (8), so all four run as ONE group of 4
    prompts = _prompts(256, (5, 6, 7, 8), seed=mode == "int4")
    handles = [eng.submit_encode(EncodeRequest(tokens=p, task=task))
               for p in prompts]
    eng.run_until_drained()

    want = _direct(model, prompts, 8, task)
    for i, (p, h) in enumerate(zip(prompts, handles)):
        res = h.result()
        assert res.finish_reason == "done"
        np.testing.assert_array_equal(np.asarray(res.value), want[i])
        # and the exact-length unbatched eager forward agrees numerically
        logits, _ = bert_classify_logits(model.params, model.plan,
                                         jnp.asarray(p[None]))
        if task == "classify":
            ref = np.asarray(logits)[0]
            np.testing.assert_allclose(np.asarray(res.value), ref,
                                       rtol=2e-5, atol=1e-7)


def test_padding_rows_are_bit_exact():
    """The model-level property the serving path is built on: a row padded
    to a bucket with its keys masked (``lengths=``) is bit-identical to the
    unpadded forward — bidirectional attention never sees the zero tail."""
    model = _encoder_model("int4")
    p = _prompts(256, (5,), seed=11)[0]
    padded = np.zeros((1, 8), np.int32)
    padded[0, :5] = p
    got, _ = bert_classify_logits(model.params, model.plan,
                                  jnp.asarray(padded),
                                  lengths=jnp.asarray([5]))
    want, _ = bert_classify_logits(model.params, model.plan,
                                   jnp.asarray(p[None]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_results_independent_of_batch_composition():
    """A request's result does not depend on which other requests share its
    group — neither their content, their lengths, nor their order."""
    model = _encoder_model("int4")
    p1, p2, p3 = _prompts(256, (5, 8, 6), seed=7)

    def run(batch):
        eng = ServingEngine(model, slots=4, max_len=64,
                            clock=VirtualClock())
        hs = {id(p): eng.submit_encode(
                  EncodeRequest(tokens=p, task="classify")) for p in batch}
        eng.run_until_drained()
        return np.asarray(hs[id(p1)].result().value)

    base = run([p1, p2])
    np.testing.assert_array_equal(base, run([p1, p3]))   # different neighbor
    np.testing.assert_array_equal(base, run([p2, p1]))   # different order


# ----------------------------------------------------- lifecycle semantics
def test_encode_deadline_shed_on_virtual_clock():
    model = _encoder_model("int8")
    clock = VirtualClock()
    eng = ServingEngine(model, slots=2, max_len=64, clock=clock)
    h = eng.submit_encode(EncodeRequest(tokens=np.arange(1, 6),
                                        deadline_s=0.05))
    clock.advance(0.1)             # past the admission deadline
    eng.engine_step()
    assert h.finished and h.finish_reason == "shed"
    assert h.result().value is None
    assert not eng.scheduler.has_work


def test_encode_cancel_while_queued():
    model = _encoder_model("int8")
    eng = ServingEngine(model, slots=2, max_len=64, clock=VirtualClock())
    seen = []
    h = eng.submit_encode(EncodeRequest(tokens=np.arange(1, 6)),
                          on_result=lambda rid, v: seen.append((rid, v)))
    assert h.cancel()
    assert h.finished and h.finish_reason == "cancelled"
    assert seen == [(h.rid, None)]
    assert not eng.scheduler.has_work
    assert not h.cancel()          # already terminal


def test_encode_priority_orders_admission():
    """Higher-priority encode requests admit first when slots are scarce."""
    model = _encoder_model("int8")
    eng = ServingEngine(model, slots=1, max_len=64, clock=VirtualClock())
    order = []
    hs = [eng.submit_encode(EncodeRequest(tokens=np.arange(1, 5),
                                          priority=pr),
                            on_result=lambda rid, v: order.append(rid))
          for pr in (0, 5, 1)]
    eng.run_until_drained()
    assert order == [hs[1].rid, hs[2].rid, hs[0].rid]


# --------------------------------------------------------- task validation
def test_bad_task_and_empty_tokens_rejected():
    with pytest.raises(ValueError, match="task"):
        EncodeRequest(tokens=np.arange(3), task="generate")
    model = _encoder_model("int8")
    eng = ServingEngine(model, slots=2, max_len=8, clock=VirtualClock())
    with pytest.raises(ValueError, match="empty"):
        eng.submit_encode(EncodeRequest(tokens=np.array([], np.int32)))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit_encode(EncodeRequest(tokens=np.arange(1, 12)))


def test_encoder_engine_rejects_generation_submit():
    model = _encoder_model("int8")
    eng = ServingEngine(model, slots=2, max_len=64, clock=VirtualClock())
    with pytest.raises(ValueError):
        eng.submit(GenerationRequest(prompt=np.arange(1, 5),
                                     max_new_tokens=2))


# ------------------------------------------------- decoder 'score' service
def test_decoder_engine_serves_score_only():
    (model, cfg) = _decoder_model()
    eng = ServingEngine(model, slots=2, max_len=64, clock=VirtualClock())
    for task in ("classify", "embed"):
        with pytest.raises(ValueError, match="score"):
            eng.submit_encode(EncodeRequest(tokens=np.arange(1, 5),
                                            task=task))


def test_decoder_score_is_batch_independent_loglikelihood():
    """score == prompt log-likelihood, and (causal ⇒) independent of batch
    composition and of the generation traffic sharing the engine."""
    (model, cfg) = _decoder_model()
    prompts = _prompts(cfg.vocab_size, (4, 7, 11), seed=3)

    def run(batch, with_gen=False):
        eng = ServingEngine(model, slots=4, max_len=64,
                            clock=VirtualClock())
        hs = [eng.submit_encode(EncodeRequest(tokens=p, task="score"))
              for p in batch]
        if with_gen:
            eng.submit(GenerationRequest(prompt=np.arange(1, 6),
                                         max_new_tokens=3))
        eng.run_until_drained()
        return [np.asarray(h.result().value) for h in hs]

    together = run(prompts, with_gen=True)
    for p, got in zip(prompts, together):
        assert got.shape == ()
        assert np.isfinite(got) and got <= 0.0    # it is a log-probability
        alone, = run([p])
        np.testing.assert_array_equal(got, alone)
