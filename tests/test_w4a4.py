"""W4A4 serving-loop tests (DESIGN.md §13).

Invariants:
* ``ops.act_quant`` equals the ``quantize_to_int`` reference for ANY row
  count (regression: the Pallas kernel asserted ``M % 256 == 0`` and
  crashed on ragged serving batches);
* activation quantization stays on the k-bit grid, round-trips within
  ``s/2`` in-range and clamps to the grid endpoints out-of-range
  (property tests);
* the Pallas int4 x int4 integer-accumulation path matches the reference
  int path to float roundoff (identical codes, different accumulation);
* ``act_bits`` is validated at plan build (bad value / no policy / fp
  fallback off the reference backend), overrides ``a_bits`` without moving
  segment boundaries, and survives plan-meta round trips — including metas
  written before the field existed (old artifacts load unchanged);
* deploy-with-override == retarget-after-deploy bit-for-bit, retargeting
  is invertible (4 -> 8 -> 4 to float roundoff) and touches ONLY ``s_a``
  leaves, each by exactly the qmax ratio;
* the fp-activation fallback never reads ``s_a`` (poison isolation,
  mirroring the KV-cache poison test in test_kv_quant.py);
* a saved W4A4 artifact reloads with its ``act_bits`` and serves token
  streams byte-identical to the in-memory model, deterministically across
  fresh engines, and the serve CLI retarget path is deterministic per
  (prompt, seed);
* the mixed-precision search (core/autosearch.py) ranks by sensitivity and
  respects the accuracy floor, with skipped layers non-terminal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.core.autosearch import search_mixed_precision
from repro.core.policy import QuantPolicy
from repro.core.quantizer import qrange, quantize_to_int
from repro.deploy import (DeployedModel, ExecutionPlan, deploy,
                          retarget_act_bits)
from repro.kernels import ops
from repro.models import api
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _cfg():
    return reduced(get_config("stablelm-3b")).replace(act="gelu")


def _w4_model(act_bits=None, backend="reference"):
    """All-int4 policy deployed from the SAME fp init + calibration batch,
    so two calls differ only in the plan."""
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend=backend, act_bits=act_bits)
    rng = np.random.default_rng(0)
    calib = [{"tokens": rng.integers(1, cfg.vocab_size, (2, 16))}]
    return deploy(api.init_model(cfg, KEY), plan, calib_batches=calib)


def _tokens(cfg, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 12)), jnp.int32)


def _logits(model, tokens):
    return np.asarray(api.forward(model.params, model.plan,
                                  tokens=tokens)[0])


def _is_sa(path):
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", None)) == "s_a"


# ------------------------------------------------------- activation quant

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m", [1, 7, 255, 256, 257, 300, 513])
def test_act_quant_any_row_count(m, bits):
    """Regression: the kernel asserted M % block == 0, so any serving batch
    whose row count wasn't a multiple of 256 crashed. Pad rows must not
    leak: the result equals the per-element reference exactly."""
    rng = np.random.default_rng(m)
    x = jnp.asarray(rng.standard_normal((m, 16)), jnp.float32)
    s = jnp.float32(0.07)
    got = np.asarray(ops.act_quant(x, s, bits))
    assert got.shape == (m, 16) and got.dtype == np.int8
    np.testing.assert_array_equal(got, np.asarray(quantize_to_int(x, s,
                                                                  bits)))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-4, 4, allow_nan=False, width=32),
                min_size=1, max_size=64),
       st.floats(0.01, 1.0), st.sampled_from([4, 8]))
def test_act_quant_round_trip_and_clip(xs, s, bits):
    qmin, qmax = qrange(bits)
    x = np.asarray(xs, np.float32).reshape(1, -1)
    codes = np.asarray(quantize_to_int(jnp.asarray(x), jnp.float32(s),
                                       bits))[0]
    assert codes.min() >= qmin and codes.max() <= qmax
    xf = x[0].astype(np.float64)
    dq = codes.astype(np.float64) * s
    in_range = (xf >= qmin * s) & (xf <= qmax * s)
    assert np.all(np.abs(dq[in_range] - xf[in_range]) <= s / 2 + 1e-5)
    assert np.all(codes[xf > qmax * s] == qmax)
    assert np.all(codes[xf < qmin * s] == qmin)


def test_pallas_w4a4_matches_reference_int_path():
    """Both backends quantize activations to the SAME codes against the
    same packed weights; only the accumulation differs (int32 in the Pallas
    kernel, fp in the reference einsum) — logits must agree to roundoff."""
    cfg = _cfg()
    tokens = _tokens(cfg)
    ref = _logits(_w4_model(act_bits=4, backend="reference"), tokens)
    pal = _logits(_w4_model(act_bits=4, backend="pallas"), tokens)
    np.testing.assert_allclose(pal, ref, rtol=0, atol=1e-4)


# ----------------------------------------------------------- plan surface

def test_plan_act_bits_validation():
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    with pytest.raises(ValueError, match="act_bits"):
        ExecutionPlan.build(cfg, pol, act_bits=3)
    with pytest.raises(ValueError, match="policy"):
        ExecutionPlan.build(cfg, None, act_bits=4)
    with pytest.raises(ValueError, match="reference"):
        ExecutionPlan.build(cfg, pol, backend="pallas", act_bits=0)


def test_act_bits_override_preserves_boundaries_and_meta():
    """a_bits is a pure function of w_bits under a policy, so a uniform
    override can never merge or split segments; and the plan meta must
    round-trip — including metas written BEFORE act_bits existed."""
    from repro.deploy.plan import plan_from_meta, plan_to_meta
    cfg = _cfg()
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers // 2)
    base = ExecutionPlan.build(cfg, pol, backend="pallas")
    over = ExecutionPlan.build(cfg, pol, backend="pallas", act_bits=4)
    assert ([(s, e) for s, e, _ in over.segments]
            == [(s, e) for s, e, _ in base.segments])
    for (_, _, sp0), (_, _, sp1) in zip(base.segments, over.segments):
        assert sp1.w_bits == sp0.w_bits
        assert sp1.a_bits == (4 if sp0.mode == "int" else sp0.a_bits)

    assert plan_from_meta(plan_to_meta(over)) == over
    old = plan_to_meta(base)
    old["build"].pop("act_bits")              # a pre-§13 artifact's meta
    assert plan_from_meta(old) == base


# ------------------------------------------------------------- retargeting

def test_retarget_equals_deploy_override():
    """The stored-scale invariant makes retargeting exact: rescaling a
    policy-grid deployment onto the int4 grid is bit-identical to deploying
    with the override. 4 -> 8 -> 4 round-trips each scale through two f32
    multiplies by reciprocal qmax ratios — equal to 1 ulp, not bit-equal."""
    cfg = _cfg()
    tokens = _tokens(cfg)
    base = _w4_model(act_bits=None)
    ret = retarget_act_bits(base, 4)
    assert ret.plan.act_bits == 4
    np.testing.assert_array_equal(_logits(ret, tokens),
                                  _logits(_w4_model(act_bits=4), tokens))
    back = retarget_act_bits(retarget_act_bits(ret, 8), 4)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6),
        ret.params, back.params)


def test_retarget_touches_only_act_scales():
    base = _w4_model(act_bits=None)
    ret = retarget_act_bits(base, 8)
    flat_a = jax.tree_util.tree_flatten_with_path(base.params)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(ret.params)[0]
    changed = []
    for (path, a), (path_b, b) in zip(flat_a, flat_b):
        assert path == path_b
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            changed.append(path)
    assert changed, "retargeting 4 -> 8 must move the stored scales"
    assert all(_is_sa(p) for p in changed), \
        f"non-s_a leaves changed: {[p for p in changed if not _is_sa(p)]}"
    # and by exactly the qmax ratio (the rescale law)
    ratio = qrange(4)[1] / qrange(8)[1]
    for (path, a), (_, b) in zip(flat_a, flat_b):
        if _is_sa(path):
            np.testing.assert_allclose(np.asarray(b),
                                       np.asarray(a) * ratio, rtol=1e-6)


def test_fp_fallback_ignores_poisoned_act_scales():
    """act_bits=0 serves dequantized weights against fp activations — the
    path must never read s_a. Poisoning every stored activation scale
    cannot change a single output bit (mirrors the KV poison test)."""
    cfg = _cfg()
    tokens = _tokens(cfg)
    fp = retarget_act_bits(_w4_model(act_bits=None), 0)
    assert fp.plan.act_bits == 0 and fp.plan.backend == "reference"
    ref = _logits(fp, tokens)
    poisoned = jax.tree_util.tree_map_with_path(
        lambda p, leaf: leaf * 1e4 if _is_sa(p) else leaf, fp.params)
    out = np.asarray(api.forward(poisoned, fp.plan, tokens=tokens)[0])
    np.testing.assert_array_equal(ref, out)


# ------------------------------------------- artifact + serving round trip

def _streams(model, prompts, max_new=4):
    eng = ServingEngine(model, slots=2, max_len=64)
    for p in prompts:
        eng.submit(Request(prompt=p.copy(), max_new_tokens=max_new))
    eng.run_until_drained()
    return {r.rid: r.out.tolist() for r in eng.done}


def test_w4a4_artifact_serve_round_trip(tmp_path):
    """deploy(act_bits=4) -> save -> load -> serve: the plan (including
    act_bits) survives, streams match the in-memory model byte-for-byte,
    and a second fresh engine repeats them (determinism per prompt)."""
    model = _w4_model(act_bits=4, backend="pallas")
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1, 8], np.int32)]
    mem = _streams(model, prompts)
    loaded = DeployedModel.load(model.save(str(tmp_path / "artifact")))
    assert loaded.plan == model.plan and loaded.plan.act_bits == 4
    assert _streams(loaded, prompts) == mem
    assert _streams(loaded, prompts) == mem


def test_serve_cli_act_bits_deterministic(tmp_path, capsys):
    """Acceptance: ``serve --artifact DIR --act-bits 4`` retargets the
    loaded model and emits deterministic streams per (prompt, seed)."""
    from repro.launch import serve
    art = str(tmp_path / "artifact")
    serve.main(["--reduced", "--requests", "2", "--slots", "1",
                "--max-len", "64", "--export", art])
    capsys.readouterr()
    args = ["--artifact", art, "--act-bits", "4", "--requests", "2",
            "--slots", "1", "--max-len", "64", "--temperature", "0.8",
            "--seed", "3", "--stream"]
    serve.main(args)
    out1 = capsys.readouterr().out
    serve.main(args)
    out2 = capsys.readouterr().out
    assert "[serve] retargeted activations to 4-bit" in out1
    stream1 = [ln for ln in out1.splitlines() if ln.startswith("[stream]")]
    stream2 = [ln for ln in out2.splitlines() if ln.startswith("[stream]")]
    assert stream1 and stream1 == stream2


# -------------------------------------------------- mixed-precision search

def test_search_ranks_by_sensitivity_and_respects_floor():
    cost = {0: 0.0, 1: 0.01, 2: 0.2, 3: 0.0}

    def score(pol):
        return 0.9 - sum(cost[l] for l in (pol.int4_layers or ()))

    res = search_mixed_precision(4, score, accuracy_floor=0.88)
    assert sorted(res.policy.int4_layers) == [0, 1, 3]
    assert res.base_accuracy == pytest.approx(0.9)
    assert res.accuracy == pytest.approx(0.89)
    # least-sensitive first, ties broken by layer index
    assert [l for l, _ in res.sensitivity] == [0, 3, 1, 2]
    # the too-sensitive layer was TRIED and refused, not silently dropped
    assert any(not ok and 2 in cand for cand, _, ok in res.trajectory)


def test_search_keeps_all_int8_when_nothing_fits():
    def score(pol):
        return 0.9 - 0.5 * len(pol.int4_layers or ())

    res = search_mixed_precision(3, score, accuracy_floor=0.89)
    assert tuple(res.policy.int4_layers or ()) == ()
    assert res.accuracy == res.base_accuracy == pytest.approx(0.9)


def test_search_floor_delta_relative_to_base():
    """floor_delta without fp_score: the floor hangs off the all-int8 base
    the search measures anyway — same outcome as the absolute floor."""
    cost = {0: 0.0, 1: 0.01, 2: 0.2, 3: 0.0}

    def score(pol):
        return 0.9 - sum(cost[l] for l in (pol.int4_layers or ()))

    res = search_mixed_precision(4, score, floor_delta=0.02)
    assert res.floor == pytest.approx(0.88)
    assert sorted(res.policy.int4_layers) == [0, 1, 3]
    assert res.accuracy == pytest.approx(0.89)


def test_search_floor_delta_relative_to_fp_score():
    """floor_delta + fp_score: 'within delta of the fp reference' — a
    tighter floor than the int8 base when fp scores higher."""
    cost = {0: 0.0, 1: 0.01, 2: 0.2, 3: 0.0}

    def score(pol):
        return 0.9 - sum(cost[l] for l in (pol.int4_layers or ()))

    res = search_mixed_precision(4, score, floor_delta=0.05, fp_score=0.95)
    assert res.floor == pytest.approx(0.90)
    assert sorted(res.policy.int4_layers) == [0, 3]   # only free layers fit
    assert res.accuracy == pytest.approx(0.9)
    assert "floor 0.9000" in res.describe()


def test_search_floor_arguments_validated():
    score = lambda pol: 0.9                            # noqa: E731
    with pytest.raises(ValueError, match="exactly one"):
        search_mixed_precision(2, score)
    with pytest.raises(ValueError, match="exactly one"):
        search_mixed_precision(2, score, accuracy_floor=0.8,
                               floor_delta=0.1)
    with pytest.raises(ValueError, match="fp_score"):
        search_mixed_precision(2, score, accuracy_floor=0.8, fp_score=0.9)
