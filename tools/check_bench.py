#!/usr/bin/env python3
"""Bench gate: fail CI when serving SLO goodput regresses vs the committed
baseline (DESIGN.md §12).

Two inputs, one verdict:

* ``BENCH_load.json`` (``python -m benchmarks.serve_load``) — **the gate**.
  For every wall-mode variant present in both runs, compare the bootstrap
  confidence interval of SLO goodput: the check fails only when the current
  interval lies ENTIRELY below the baseline interval (``cur.hi < base.lo``).
  A point threshold on a noisy scalar flapped run-to-run (the old >30%
  tok/s gate tripped twice on scheduler jitter alone); interval overlap
  cannot — run-to-run noise widens the intervals, and overlapping intervals
  are exactly the statement "this difference is not resolvable at this
  sample size". Goodput itself is host-normalized by construction: the
  bench self-calibrates its SLO thresholds and offered rate from measured
  step costs on the same host, so a dev-box baseline gates slower CI
  runners. The virtual-clock section is compared too (WARN on drift, never
  FAIL here: cross-version numpy may legally reshuffle arrival streams);
  its run-to-run determinism is asserted byte-exactly in CI by diffing two
  back-to-back runs.

* ``BENCH_serve.json`` (``python -m benchmarks.serve_latency``) —
  **informational only**. Normalized tok/s per variant and the
  repeated-prefix scenario are printed for the CI log so trends stay
  visible, but they no longer fail the build.

Usage:
  python tools/check_bench.py [--load-current BENCH_load.json]
                              [--load-baseline benchmarks/BENCH_load_baseline.json]
                              [--current BENCH_serve.json]
                              [--baseline benchmarks/BENCH_serve_baseline.json]
                              [--update]   # rewrite baselines from current
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_CURRENT = "BENCH_serve.json"
DEFAULT_BASELINE = ROOT / "benchmarks" / "BENCH_serve_baseline.json"
DEFAULT_LOAD_CURRENT = "BENCH_load.json"
DEFAULT_LOAD_BASELINE = ROOT / "benchmarks" / "BENCH_load_baseline.json"
REFERENCE_VARIANT = "fp32_kv16"


def load(path: pathlib.Path, key: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if key not in data:
        raise SystemExit(f"FAIL: {path} has no {key!r} key")
    return data


def _fmt_ci(ci: dict) -> str:
    return f"{ci['mean']:.3f} [{ci['lo']:.3f}, {ci['hi']:.3f}]"


# ------------------------------------------------------------- goodput gate
def check_goodput(current: dict, baseline: dict) -> list[str]:
    """Interval-overlap gate over the wall section + virtual drift report.
    Returns the list of failed variant names."""
    failures = []
    base_wall = baseline.get("wall", {})
    cur_wall = current.get("wall", {})
    for name, base in sorted(base_wall.items()):
        cur = cur_wall.get(name)
        if cur is None:
            print(f"WARN: load variant {name!r} missing from current run")
            continue
        b = base["summary"].get("goodput")
        c = cur["summary"].get("goodput")
        if b is None or c is None:
            print(f"WARN: load variant {name!r} has no goodput CI; skipping")
            continue
        # the gate: current interval entirely below the baseline interval
        bad = c["hi"] < b["lo"]
        status = "FAIL" if bad else "ok"
        n = cur["summary"].get("n_counted", "?")
        print(f"{status}: goodput {name}: {_fmt_ci(c)} vs baseline "
              f"{_fmt_ci(b)} (n={n}, "
              f"shed {cur['summary'].get('n_shed', 0)}, "
              f"rejected {cur['summary'].get('n_rejected', 0)})")
        for key in ("ttft_p99_ms", "itl_p99_ms", "queue_wait_p99_ms"):
            ci = cur["summary"].get(key)
            if ci is not None:
                print(f"    {key}: {_fmt_ci(ci)}")
        if bad:
            failures.append(name)
    for name in sorted(set(cur_wall) - set(base_wall)):
        print(f"NOTE: new load variant {name!r} has no baseline yet")

    # virtual section: deterministic per (machine, numpy); report drift but
    # never fail against a baseline that may have been recorded under a
    # different numpy (distribution streams are not version-stable). CI
    # separately asserts two back-to-back runs are byte-identical.
    for name, base in sorted(baseline.get("virtual", {}).items()):
        cur = current.get("virtual", {}).get(name)
        if cur is None:
            print(f"WARN: virtual scenario {name!r} missing from current run")
            continue
        if "summary" not in base or "summary" not in cur:
            continue     # differently-shaped cells (e.g. paged_capacity)
        bg = base["summary"].get("goodput", {}).get("mean")
        cg = cur["summary"].get("goodput", {}).get("mean")
        drift = (bg is not None and cg is not None
                 and abs(bg - cg) > 1e-9)
        tag = "WARN" if drift else "INFO"
        print(f"{tag}: virtual {name}: goodput {cg}, shed "
              f"{cur['summary'].get('n_shed')}, rejected "
              f"{cur['summary'].get('n_rejected')}"
              + (f" (baseline goodput {bg} — scheduling behavior drifted; "
                 "re-record if intentional)" if drift else ""))
        # multi-tenant scenarios: per-tenant goodput so the fair-share
        # split stays visible in the CI log (DESIGN.md §14)
        for tenant, ts in sorted(
                (cur["summary"].get("by_tenant") or {}).items()):
            print(f"    tenant {tenant}: goodput {ts.get('goodput')} "
                  f"({ts.get('n_good')}/{ts.get('n_counted')} good)")

    # paged-vs-dense capacity scenario (DESIGN.md §15): shaped unlike the
    # goodput scenarios (no summary/goodput CI), so it is reported from the
    # CURRENT run here; CI's determinism check asserts its ratio floor.
    cap = current.get("virtual", {}).get("paged_capacity")
    if cap is not None:
        print(f"INFO: virtual paged_capacity: "
              f"{cap['paged']['peak_concurrent']} paged vs "
              f"{cap['dense']['peak_concurrent']} dense concurrent under "
              f"{cap['budget_bytes'] >> 10}KiB "
              f"({cap['capacity_ratio']:.1f}x), "
              f"streams_match={cap['streams_match']}")

    # replica scaling scenario (DESIGN.md §16) — a HARD gate, unlike the
    # drift reports above: the virtual cost model is deterministic, so a
    # 2-replica set below 1.8x single-engine capacity (or any stream
    # divergence between the runs) is a real scheduling/dispatch bug,
    # never noise.
    rep = current.get("virtual", {}).get("replica_scale")
    if rep is not None:
        ratio = rep["capacity_ratio"]
        n_rep = rep.get("replica_count", 2)
        goodputs = (rep["single"]["goodput"]["mean"],
                    rep["replicas"]["goodput"]["mean"])
        ok = (ratio >= 1.8 and rep["streams_match"]
              and goodputs == (1.0, 1.0))
        print(f"{'ok' if ok else 'FAIL'}: virtual replica_scale: "
              f"{n_rep} replicas {ratio:.2f}x single-engine capacity "
              f"(floor 1.8x), goodput {goodputs[1]:.2f}/{goodputs[0]:.2f}, "
              f"streams_match={rep['streams_match']}")
        if not ok:
            failures.append("replica_scale")
    return failures


# --------------------------------------------------- tok/s (informational)
def report_throughput(current: dict, baseline: dict) -> None:
    """The old single-burst tok/s comparison, now purely informational."""
    def ref_tps(data, label):
        ref = data["variants"].get(REFERENCE_VARIANT)
        if ref is None:
            print(f"WARN: {label} run lacks {REFERENCE_VARIANT!r}; "
                  "skipping tok/s report")
            return None
        return ref["tokens_per_s"]

    cur_ref = ref_tps(current, "current")
    base_ref = ref_tps(baseline, "baseline")
    if cur_ref is None or base_ref is None:
        return
    for name, base in sorted(baseline["variants"].items()):
        cur = current["variants"].get(name)
        if cur is None:
            print(f"WARN: variant {name!r} missing from current run")
            continue
        if "tokens_per_s" not in cur or "tokens_per_s" not in base:
            continue
        b = base["tokens_per_s"] / base_ref
        c = cur["tokens_per_s"] / cur_ref
        ttft = cur.get("ttft_p50_ms")
        extra = f", ttft p50 {ttft:.1f}ms" if ttft is not None else ""
        print(f"INFO: tok/s {name}: {c:.3f}x of {REFERENCE_VARIANT} "
              f"({cur['tokens_per_s']:.1f} tok/s) vs baseline {b:.3f}x "
              f"({base['tokens_per_s']:.1f} tok/s){extra}")
    for name, s in sorted(current.get("prefix_scenario", {}).items()):
        hit = s.get("prefix_hit_rate")
        hit_txt = f", hit rate {hit:.0%}" if hit is not None else ""
        print(f"INFO: prefix {name}: {s.get('prefill_tokens', '?')} prefill "
              f"tok computed{hit_txt}, "
              f"ttft p50 {s.get('ttft_p50_ms', 0):.1f}ms")


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--current", default=DEFAULT_CURRENT)
    p.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    p.add_argument("--load-current", default=DEFAULT_LOAD_CURRENT)
    p.add_argument("--load-baseline", default=str(DEFAULT_LOAD_BASELINE))
    p.add_argument("--update", action="store_true",
                   help="overwrite the committed baselines with the current "
                        "results (whichever current files exist)")
    args = p.parse_args()

    if args.update:
        updated = []
        for cur_path, base_path, key in (
                (args.load_current, args.load_baseline, "wall"),
                (args.current, args.baseline, "variants")):
            cur_path = pathlib.Path(cur_path)
            if not cur_path.exists():
                print(f"NOTE: {cur_path} absent; baseline not updated")
                continue
            with open(base_path, "w") as f:
                json.dump(load(cur_path, key), f, indent=2, sort_keys=True)
            updated.append(str(base_path))
        print(f"OK: baselines updated -> {', '.join(updated) or 'none'}")
        return 0

    failures: list[str] = []

    # --- the gate: SLO-goodput confidence intervals (BENCH_load.json)
    load_base_path = pathlib.Path(args.load_baseline)
    if load_base_path.exists():
        load_cur_path = pathlib.Path(args.load_current)
        if not load_cur_path.exists():
            print(f"FAIL: {load_cur_path} missing but a goodput baseline is "
                  f"committed ({load_base_path}) — run "
                  "`python -m benchmarks.serve_load --quick` first")
            return 1
        failures += check_goodput(load(load_cur_path, "wall"),
                                  load(load_base_path, "wall"))
    else:
        print(f"NOTE: no goodput baseline at {load_base_path}; "
              "goodput gate skipped")

    # --- informational: single-burst tok/s (BENCH_serve.json); pass an
    # empty --current/--baseline to skip the report entirely
    cur_path = pathlib.Path(args.current or "/nonexistent")
    base_path = pathlib.Path(args.baseline or "/nonexistent")
    if cur_path.exists() and base_path.exists():
        report_throughput(load(cur_path, "variants"),
                          load(base_path, "variants"))
    else:
        print(f"NOTE: tok/s report skipped ({cur_path} or {base_path} "
              "absent)")

    if failures:
        print(f"FAIL: {len(failures)} variant(s) with goodput below the "
              f"baseline interval: {', '.join(failures)}")
        return 1
    print("OK: SLO goodput within the baseline confidence interval for "
          "every gated variant")
    return 0


if __name__ == "__main__":
    sys.exit(main())
