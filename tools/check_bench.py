#!/usr/bin/env python3
"""Bench gate: fail CI when serving throughput regresses vs the committed
baseline.

Compares every variant of a fresh ``BENCH_serve.json`` (written by
``python -m benchmarks.serve_latency``) against
``benchmarks/BENCH_serve_baseline.json``. Absolute interpret-mode tok/s is
machine-dependent (the baseline is recorded on a dev box, CI runs on shared
runners), so the gate is on NORMALIZED throughput: each variant's tok/s
divided by the same run's ``fp32_kv16`` tok/s. That ratio cancels host
speed and pins what the serving rework actually owns — the relative cost of
the quantized/pallas paths vs the fp path. A variant fails when its ratio
drops more than ``--max-regression`` (default 30%) below the baseline
ratio. Absolute tok/s is still printed, and a collapse of the reference
variant itself (> 10x slower than baseline) fails too, as that signals a
broken harness rather than a slow runner.

Variants present only on one side are reported but never fail the gate (so
adding a variant doesn't require a lockstep baseline bump). Likewise the
``prefix_scenario`` section and any variant entry without ``tokens_per_s``
(token-count scenarios) are printed for the CI log but never gated — the
prefix-reuse claim is asserted deterministically in the test suite.

Usage:
  python tools/check_bench.py [--current BENCH_serve.json]
                              [--baseline benchmarks/BENCH_serve_baseline.json]
                              [--max-regression 0.30]
  python tools/check_bench.py --update   # rewrite the baseline from current
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_CURRENT = "BENCH_serve.json"
DEFAULT_BASELINE = ROOT / "benchmarks" / "BENCH_serve_baseline.json"
REFERENCE_VARIANT = "fp32_kv16"


def load(path: pathlib.Path) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "variants" not in data:
        raise SystemExit(f"FAIL: {path} has no 'variants' key")
    return data


def _ref_tps(data: dict, label: str) -> float:
    ref = data["variants"].get(REFERENCE_VARIANT)
    if ref is None:
        raise SystemExit(
            f"FAIL: {label} run lacks the {REFERENCE_VARIANT!r} reference "
            "variant needed for host-speed normalization")
    return ref["tokens_per_s"]


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--current", default=DEFAULT_CURRENT)
    p.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    p.add_argument("--max-regression", type=float, default=0.30,
                   help="fail when normalized tok/s drops more than this "
                        "fraction below the baseline ratio")
    p.add_argument("--update", action="store_true",
                   help="overwrite the baseline with the current results")
    args = p.parse_args()

    current = load(pathlib.Path(args.current))
    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
        print(f"OK: baseline updated -> {args.baseline}")
        return 0

    baseline = load(pathlib.Path(args.baseline))
    cur_ref = _ref_tps(current, "current")
    base_ref = _ref_tps(baseline, "baseline")

    failures = []
    if cur_ref < base_ref / 10.0:
        print(f"FAIL: reference variant {REFERENCE_VARIANT} collapsed: "
              f"{cur_ref:.1f} tok/s vs baseline {base_ref:.1f} (>10x) — "
              "harness breakage, not host speed")
        failures.append(REFERENCE_VARIANT)

    for name, base in sorted(baseline["variants"].items()):
        if name == REFERENCE_VARIANT:
            continue
        cur = current["variants"].get(name)
        if cur is None:
            print(f"WARN: variant {name!r} missing from current run")
            continue
        if "tokens_per_s" not in cur or "tokens_per_s" not in base:
            # newer runs may carry non-throughput entries (e.g. token-count
            # scenarios); they are informational, never gated
            print(f"NOTE: variant {name!r} has no tokens_per_s; skipping")
            continue
        b = base["tokens_per_s"] / base_ref
        c = cur["tokens_per_s"] / cur_ref
        floor = b * (1.0 - args.max_regression)
        status = "FAIL" if c < floor else "ok"
        # newer runs carry extra per-request keys (ttft_*/queue_wait_*,
        # DESIGN.md §10); they are informational here — the gate keys on
        # tokens_per_s only, so old baselines without them stay valid
        ttft = cur.get("ttft_p50_ms")
        extra = f", ttft p50 {ttft:.1f}ms" if ttft is not None else ""
        print(f"{status}: {name}: {c:.3f}x of {REFERENCE_VARIANT} "
              f"({cur['tokens_per_s']:.1f} tok/s) vs baseline {b:.3f}x "
              f"({base['tokens_per_s']:.1f} tok/s), floor {floor:.3f}x"
              f"{extra}")
        if c < floor:
            failures.append(name)
    for name in sorted(set(current["variants"]) - set(baseline["variants"])):
        print(f"NOTE: new variant {name!r} has no baseline yet")

    # repeated-prefix scenario (DESIGN.md §11): informational, NEVER gated —
    # interpret-mode wall clocks are host-noisy, and the reuse claim
    # (fewer prefill tokens computed) is asserted deterministically in the
    # test suite instead. Printed so regressions are visible in CI logs.
    for name, s in sorted(current.get("prefix_scenario", {}).items()):
        hit = s.get("prefix_hit_rate")
        hit_txt = f", hit rate {hit:.0%}" if hit is not None else ""
        print(f"INFO: prefix {name}: {s.get('prefill_tokens', '?')} prefill "
              f"tok computed{hit_txt}, "
              f"ttft p50 {s.get('ttft_p50_ms', 0):.1f}ms")

    if failures:
        print(f"FAIL: {len(failures)} variant(s) regressed >"
              f"{args.max_regression:.0%}: {', '.join(failures)}")
        return 1
    print("OK: no serving-throughput regression beyond "
          f"{args.max_regression:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
