#!/usr/bin/env python3
"""Quality gate: fail CI when the deployed W4A4 artifact loses accuracy
(DESIGN.md §13).

Input: ``BENCH_quality.json`` from
``python -m benchmarks.table1_glue --quick --artifact DIR --out ...`` —
the fp student vs the cold W4A4 artifact (export → save → load → score) on
the synthetic GLUE-style task. Two checks, both tolerance-banded:

1. **The paper claim** — ``fp_acc - w4a4_acc <= --max-delta``: deployed
   4-bit weights AND activations hold accuracy against the fp reference.
   Gated against the current run's own fp baseline, so it is
   host-normalized by construction (both numbers come from one host).
2. **Regression vs the committed baseline** — ``w4a4_acc`` must not fall
   more than ``--tolerance`` below ``benchmarks/BENCH_quality_baseline.json``.
   The band absorbs cross-host float drift; on ONE host the bench is
   seeded end-to-end, so CI runs it twice back-to-back and gates both runs
   (the flap check, mirroring bench-smoke).

Everything else (weight-only parity row, prediction agreement, the
mixed-precision search result) is printed as INFO for the CI log.

Usage:
  python tools/check_quality.py [--current BENCH_quality.json]
                                [--baseline benchmarks/BENCH_quality_baseline.json]
                                [--tolerance 0.05] [--max-delta 0.05]
                                [--update]   # rewrite the baseline from current
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_CURRENT = "BENCH_quality.json"
DEFAULT_BASELINE = ROOT / "benchmarks" / "BENCH_quality_baseline.json"


def load(path: pathlib.Path) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "quality" not in data:
        raise SystemExit(f"FAIL: {path} has no 'quality' key")
    return data


def check(current: dict, baseline: dict, tolerance: float,
          max_delta: float) -> list[str]:
    failures = []
    cur, base = current["quality"], baseline["quality"]

    delta = cur["fp_acc"] - cur["w4a4_acc"]
    bad = delta > max_delta
    failures += ["delta"] if bad else []
    print(f"{'FAIL' if bad else 'ok'}: W4A4 vs fp delta {delta:+.4f} "
          f"(fp {cur['fp_acc']:.4f}, w4a4 {cur['w4a4_acc']:.4f}, "
          f"max allowed {max_delta:+.4f})")

    floor = base["w4a4_acc"] - tolerance
    bad = cur["w4a4_acc"] < floor
    failures += ["w4a4_acc"] if bad else []
    print(f"{'FAIL' if bad else 'ok'}: w4a4_acc {cur['w4a4_acc']:.4f} vs "
          f"baseline {base['w4a4_acc']:.4f} (floor {floor:.4f})")

    print(f"INFO: weight-only (afp) acc {cur['weight_only_acc']:.4f}, "
          f"prediction agreement {cur['agreement']:.4f} "
          f"(baseline {base['agreement']:.4f}), "
          f"n_eval {cur.get('n_eval', '?')}")
    s = current.get("search")
    if s:
        print(f"INFO: mixed-precision search: int4_layers="
              f"{s['chosen_int4_layers']} acc {s['accuracy']:.4f} "
              f"(all-int8 base {s['base_int8_acc']:.4f}, "
              f"floor {s['floor']:.4f})")
    return failures


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--current", default=DEFAULT_CURRENT)
    p.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    p.add_argument("--tolerance", type=float, default=0.05,
                   help="allowed w4a4_acc drop vs the committed baseline "
                        "(absorbs cross-host float drift)")
    p.add_argument("--max-delta", type=float, default=0.05,
                   help="allowed fp-vs-W4A4 accuracy gap within the "
                        "current run (the paper claim)")
    p.add_argument("--update", action="store_true",
                   help="overwrite the committed baseline with the current "
                        "results")
    args = p.parse_args()

    cur_path = pathlib.Path(args.current)
    if args.update:
        data = load(cur_path)
        data["quality"].pop("artifact", None)  # host-local temp path
        with open(args.baseline, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"OK: baseline updated -> {args.baseline}")
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"NOTE: no quality baseline at {base_path}; gate skipped "
              "(run with --update to record one)")
        return 0
    failures = check(load(cur_path), load(base_path),
                     args.tolerance, args.max_delta)
    if failures:
        print(f"FAIL: quality gate: {', '.join(failures)}")
        return 1
    print("OK: deployed W4A4 accuracy within tolerance of the fp reference "
          "and the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
