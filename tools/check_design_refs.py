#!/usr/bin/env python3
"""Docs check: every `DESIGN.md §N` reference in src/ (and tests/, examples/,
benchmarks/) must resolve to a real `## §N` section heading in DESIGN.md.

Exit 1 with a listing of dangling references otherwise. Run from the repo
root:  python tools/check_design_refs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
REF = re.compile(r"DESIGN\.md\s+§(\d+)")
HEADING = re.compile(r"^##\s+§(\d+)\b", re.M)


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        return 1
    sections = set(HEADING.findall(design.read_text()))

    dangling = []
    for sub in ("src", "tests", "examples", "benchmarks"):
        for path in sorted((ROOT / sub).rglob("*.py")):
            for i, line in enumerate(path.read_text().splitlines(), 1):
                for sec in REF.findall(line):
                    if sec not in sections:
                        dangling.append(
                            f"{path.relative_to(ROOT)}:{i}: DESIGN.md §{sec}")
    if dangling:
        print(f"FAIL: {len(dangling)} dangling DESIGN.md references "
              f"(sections present: {sorted(sections)}):")
        print("\n".join(dangling))
        return 1
    print(f"OK: all DESIGN.md § references resolve "
          f"(sections: {sorted(sections)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
