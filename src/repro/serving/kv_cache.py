"""Slot-state manager: per-layer KV cache with per-slot lengths (DESIGN.md §7)
and optional int8/int4 quantization (DESIGN.md §8).

fp (kv_bits=16): one stacked buffer {'k','v': (L, slots, max_len, Hkv, hd),
'len': (slots,)}. Quantized (kv_bits=8/4): the packed layout
{'k_q','v_q': integer codes (int4 nibble-packed along head_dim),
'k_scale','v_scale': (L, slots, max_len, Hkv) f32 per-(token, head) scales,
'len': (slots,)}.

Each slot masks and appends at its OWN cursor, so refilling a finished slot
with a new request cannot read the previous occupant's entries — the seed
engine's single global cursor could (stale rows below the shared ``len``
stayed attendable across refills). Per-token scales keep that property under
quantization: a slot's rows never share a scale with another slot or token.

Prefill writes through the quantizer: the batch-1 prefill cache stays fp (one
forward at full precision), and ``insert_prefill`` quantizes its rows on the
way into the slot buffers. Decode appends quantize in
``models/transformer.write_new_kv``.

All mutations are jitted with donated operands so XLA aliases the cache
buffers instead of copying the whole table per admission.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..kernels.kv_pack import kv_buffer_keys, quantize_kv
from ..models import api


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(state, slot):
    return {key: (val.at[slot].set(0) if key == "len"
                  else val.at[:, slot].set(jnp.zeros((), val.dtype)))
            for key, val in state.items()}


def _take_row(pstate, key, row):
    """One batch row of a (possibly batch-N) prefill/scratch cache buffer:
    (L, n, bucket, ...) -> (L, bucket, ...)."""
    return jax.lax.dynamic_index_in_dim(pstate[key], row, 1, keepdims=False)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("bucket",))
def _insert(state, pstate, slot, length, bucket: int, row):
    """Scatter row ``row`` of a batch-N prefill cache (L, n, bucket, H, hd)
    into ``slot``.

    Rows past ``length`` hold prompt padding; they stay masked (pos >= len)
    and are overwritten by subsequent decode writes at the slot cursor.
    """
    return {"k": state["k"].at[:, slot, :bucket].set(
                _take_row(pstate, "k", row)),
            "v": state["v"].at[:, slot, :bucket].set(
                _take_row(pstate, "v", row)),
            "len": state["len"].at[slot].set(length)}


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("bucket", "bits"))
def _insert_quant(state, pstate, slot, length, bucket: int, bits: int, row):
    """Quantize-on-insert: the fp prefill rows become packed codes plus
    per-(token, head) scales as they scatter into ``slot``."""
    kq, ks = quantize_kv(_take_row(pstate, "k", row), bits)  # (L,bucket,H,*)
    vq, vs = quantize_kv(_take_row(pstate, "v", row), bits)
    return {"k_q": state["k_q"].at[:, slot, :bucket].set(kq),
            "v_q": state["v_q"].at[:, slot, :bucket].set(vq),
            "k_scale": state["k_scale"].at[:, slot, :bucket].set(ks),
            "v_scale": state["v_scale"].at[:, slot, :bucket].set(vs),
            "len": state["len"].at[slot].set(length)}


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("bucket", "keys"))
def _copy_rows(state, src, slot, length, bucket: int, keys: tuple, row):
    """Direct same-layout scatter: row ``row`` of a scratch cache whose
    buffers already match the slot table's precision (quantized codes +
    scales, or fp rows) copies into ``slot`` — no requantization. The
    scratch may hold MORE than ``bucket`` token rows (block-grid rounding);
    only the first ``bucket`` copy."""
    out = {key: state[key].at[:, slot, :bucket].set(
              _take_row(src, key, row)[:, :bucket])
           for key in keys}
    out["len"] = state["len"].at[slot].set(length)
    return out


class SlotKVCache:
    """Slot table over the transformer-family decode cache."""

    def __init__(self, cfg: ModelConfig, slots: int, max_len: int,
                 dtype=jnp.float32, kv_bits: int | None = None, mesh=None):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
        self.state = api.decode_state(cfg, slots, max_len, dtype=dtype,
                                      per_slot_len=True,
                                      kv_bits=self.kv_bits)
        if mesh is not None:
            # tensor-parallel serving (DESIGN.md §16): KV heads partition
            # over the "model" axis; cursors replicate. The donated jitted
            # mutations above then keep the placement — donation aliases
            # the sharded buffers in place.
            from ..distributed.sharding import (place_serving,
                                                serving_state_specs)
            self.state = place_serving(
                self.state, mesh, serving_state_specs(self.state, mesh))

    @classmethod
    def from_plan(cls, plan, slots: int, max_len: int,
                  mesh=None) -> "SlotKVCache":
        """Slot table with the plan's decode dtype and KV precision — the
        engine allocates through here so the cache can never disagree with
        the plan the prefill/decode steps were built from."""
        return cls(plan.cfg, slots, max_len, dtype=plan.jnp_dtype,
                   kv_bits=plan.kv_bits, mesh=mesh)

    @property
    def quantized(self) -> bool:
        return self.kv_bits in (8, 4)

    def reset_slot(self, slot: int) -> None:
        """Zero a slot's K/V rows (codes AND scales when quantized) and
        rewind its cursor (request eviction)."""
        self.state = _reset(self.state, jnp.int32(slot))

    def insert_prefill(self, slot: int, pstate, length: int,
                       bucket: int, row: int = 0) -> None:
        """Install row ``row`` of a prefilled batch-N fp cache (allocated
        with max_len=bucket) into ``slot`` with the slot cursor at
        ``length``, quantizing the rows on the way in when kv_bits < 16."""
        assert bucket <= self.max_len, (bucket, self.max_len)
        if self.quantized:
            self.state = _insert_quant(self.state, pstate, jnp.int32(slot),
                                       jnp.int32(length), bucket,
                                       self.kv_bits, jnp.int32(row))
        else:
            self.state = _insert(self.state, pstate, jnp.int32(slot),
                                 jnp.int32(length), bucket, jnp.int32(row))

    def insert_rows(self, slot: int, src, length: int, bucket: int,
                    row: int = 0) -> None:
        """Install row ``row`` of a scratch cache that ALREADY matches this
        table's precision (the prefix-reuse chunked-prefill path, DESIGN.md
        §11): quantized codes + per-(token, head) scales — or fp rows at
        kv_bits=16 — copy directly, no requantization."""
        assert bucket <= self.max_len, (bucket, self.max_len)
        keys = kv_buffer_keys(self.kv_bits)
        self.state = _copy_rows(self.state, src, jnp.int32(slot),
                                jnp.int32(length), bucket, keys,
                                jnp.int32(row))

    def lengths(self) -> np.ndarray:
        return np.asarray(self.state["len"])
