"""Slot-state manager: per-layer KV cache with per-slot lengths (DESIGN.md §7).

The decode cache is one stacked buffer {'k','v': (L, slots, max_len, Hkv, hd),
'len': (slots,)}. Each slot masks and appends at its OWN cursor, so refilling
a finished slot with a new request cannot read the previous occupant's
entries — the seed engine's single global cursor could (stale rows below the
shared ``len`` stayed attendable across refills).

All mutations are jitted with donated operands so XLA aliases the cache
buffers instead of copying the whole table per admission.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import api


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(state, slot):
    return {"k": state["k"].at[:, slot].set(0),
            "v": state["v"].at[:, slot].set(0),
            "len": state["len"].at[slot].set(0)}


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("bucket",))
def _insert(state, pstate, slot, length, bucket: int):
    """Scatter a batch-1 prefill cache (L, 1, bucket, H, hd) into ``slot``.

    Rows past ``length`` hold prompt padding; they stay masked (pos >= len)
    and are overwritten by subsequent decode writes at the slot cursor.
    """
    return {"k": state["k"].at[:, slot, :bucket].set(pstate["k"][:, 0]),
            "v": state["v"].at[:, slot, :bucket].set(pstate["v"][:, 0]),
            "len": state["len"].at[slot].set(length)}


class SlotKVCache:
    """Slot table over the transformer-family decode cache."""

    def __init__(self, cfg: ModelConfig, slots: int, max_len: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.state = api.decode_state(cfg, slots, max_len, dtype=dtype,
                                      per_slot_len=True)

    def reset_slot(self, slot: int) -> None:
        """Zero a slot's K/V rows and rewind its cursor (request eviction)."""
        self.state = _reset(self.state, jnp.int32(slot))

    def insert_prefill(self, slot: int, pstate, length: int,
                       bucket: int) -> None:
        """Install a prefilled batch-1 cache (allocated with max_len=bucket)
        into ``slot`` with the slot cursor at ``length``."""
        assert bucket <= self.max_len, (bucket, self.max_len)
        self.state = _insert(self.state, pstate, jnp.int32(slot),
                             jnp.int32(length), bucket)

    def lengths(self) -> np.ndarray:
        return np.asarray(self.state["len"])
