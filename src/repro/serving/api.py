"""Generation API: requests, sampling, token streams (DESIGN.md §10).

The public request/response surface of the serving engine. Three layers:

* **request types** — :class:`GenerationRequest` (prompt + sampling + stop
  conditions + priority/deadline) and :class:`SamplingParams`; the seed-era
  :class:`Request` stays as a thin deprecation shim (same fields, greedy
  defaults) mirroring the plan-shim pattern of DESIGN.md §9.
* **handles** — ``engine.submit(req)`` returns a :class:`TokenStream` that
  yields tokens as the engine produces them (iterator form) and/or invokes a
  per-token callback; ``stream.result()`` pumps to completion and returns a
  :class:`GenerationResult`.
* **sampling math** — :func:`sample_token` (one logits row) and its vmapped
  batch form :func:`sample_batch`. Greedy decoding is exactly
  ``temperature=0`` (a raw-logits argmax, bit-identical to the legacy path);
  otherwise temperature → top-k mask → top-p (nucleus) mask → categorical
  draw. The PRNG key is ``fold_in(PRNGKey(seed), step)`` where ``step`` is
  the request's OWN generated-token index, so a request's stream depends only
  on (prompt, seed), never on which other requests share the batch.

This module is a leaf: it must not import the engine/scheduler (they import
it), and ``repro.deploy.plan`` imports it lazily for the plan's resolved
sampling defaults.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SamplingParams", "GenerationRequest", "GenerationResult",
           "TokenStream", "Request", "QueueFullError", "FINISH_REASONS",
           "sample_token", "sample_batch", "sample_seed"]

#: Terminal states of a request: hit ``max_new_tokens`` / emitted a stop
#: token / cancelled via ``cancel(rid)`` / shed at admission past deadline.
FINISH_REASONS = ("length", "stop", "cancelled", "shed")


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when the scheduler's bounded queue is full —
    backpressure for the caller instead of silent unbounded growth."""


# --------------------------------------------------------------- parameters
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding knobs. The default is greedy decoding.

    temperature  0 (default) is greedy argmax — exact, PRNG-free; > 0 scales
                 logits before the softmax draw.
    top_k        keep only the k highest logits (0 disables).
    top_p        nucleus sampling: keep the smallest prefix of the sorted
                 distribution with cumulative probability >= top_p
                 (1.0 disables).
    seed         PRNG seed; a request's stream is a pure function of
                 (prompt, seed) regardless of batch composition.
    n            number of independent samples to draw from ONE prompt.
                 ``submit`` fans an ``n > 1`` request into ``n`` children
                 (one stream each); sample ``i`` decodes with seed
                 ``sample_seed(seed, i)``, so every sample's stream is a
                 pure function of (prompt, seed, sample_index). Sample 0
                 keeps the request's own seed — identical to ``n=1``. On a
                 paged-KV engine the samples share the prompt's blocks
                 copy-on-write; dense engines serve the same streams by
                 plain expansion. Greedy (temperature=0) samples are all
                 identical by construction.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    n: int = 1

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    @classmethod
    def resolve(cls, value) -> "SamplingParams":
        """None → greedy defaults; dict → kwargs (artifact meta round trip);
        SamplingParams → itself."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(f"sampling must be SamplingParams, dict or None, "
                        f"got {type(value).__name__}")


def sample_seed(seed: int, index: int) -> int:
    """Per-sample decode seed for ``SamplingParams.n`` fanout: sample 0
    keeps the request's seed (its stream IS the n=1 stream); sample i > 0
    derives a distinct seed by a golden-ratio stride, kept positive for
    ``PRNGKey``. Pure arithmetic — the same (prompt, seed, i) always decodes
    the same stream on any engine layout."""
    if index == 0:
        return seed
    return (seed + 0x9E3779B9 * index) & 0x7FFFFFFF


# ----------------------------------------------------------------- requests
@dataclasses.dataclass
class GenerationRequest:
    """A generation job: prompt + sampling + stop conditions + admission.

    sampling     None inherits the plan's ``default_sampling`` at submit.
    stop_tokens  emitting any of these ends the request early
                 (``finish_reason='stop'``); the stop token IS the stream's
                 final token.
    priority     higher admits first; FIFO within a priority level.
    deadline_s   seconds after submit by which the request must be ADMITTED;
                 past it the scheduler sheds it (``finish_reason='shed'``,
                 empty output) instead of decoding tokens nobody is waiting
                 for.
    """

    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None
    stop_tokens: frozenset = frozenset()
    priority: int = 0
    deadline_s: Optional[float] = None
    out: Optional[np.ndarray] = None
    rid: int = -1                   # assigned by the scheduler on submit
    finish_reason: Optional[str] = None
    # monotonic-clock stamps, filled in by scheduler/engine (repr noise)
    submit_t: Optional[float] = dataclasses.field(default=None, repr=False)
    admit_t: Optional[float] = dataclasses.field(default=None, repr=False)
    first_token_t: Optional[float] = dataclasses.field(default=None,
                                                       repr=False)
    # n>1 fanout bookkeeping (set by submit): children of one n>1 request
    # share a fork_group id — a paged engine prefilling several members of
    # one group in the same batch shares the prompt blocks copy-on-write.
    fork_group: Optional[int] = dataclasses.field(default=None, repr=False)
    sample_index: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        self.stop_tokens = frozenset(int(t) for t in self.stop_tokens)
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")

    # ------------------------------------------------------------- timing
    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.submit_t is None or self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (submit → first emitted token)."""
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    def result(self) -> "GenerationResult":
        assert self.finish_reason is not None, \
            f"request {self.rid} has not finished"
        return GenerationResult(rid=self.rid, tokens=self.out,
                                finish_reason=self.finish_reason,
                                ttft_s=self.ttft_s,
                                queue_wait_s=self.queue_wait_s)


@dataclasses.dataclass
class Request(GenerationRequest):
    """DEPRECATED shim — build a :class:`GenerationRequest` instead.

    The seed-era ``Request(prompt, max_new_tokens)`` surface, kept so
    existing call sites keep working unchanged: greedy (plan-default)
    sampling, no stop tokens, priority 0, no deadline. ``out``/``rid``
    behave exactly as before.
    """


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """Terminal snapshot of a finished request."""

    rid: int
    tokens: np.ndarray              # trimmed output (empty for shed/queued-
    finish_reason: str              # cancel); one of FINISH_REASONS
    ttft_s: Optional[float]
    queue_wait_s: Optional[float]


# ------------------------------------------------------------------ streams
class TokenStream:
    """Live handle to a submitted request: iterate tokens as produced.

    The engine is single-threaded — callers pump it. The iterator form pumps
    ``engine.engine_step()`` under the hood whenever no token is buffered, so
    ``for tok in stream`` yields tokens as each engine step produces them.
    The callback form (``on_token(rid, token)``) fires from inside the
    engine's step, for callers running their own pump loop.

    ``stream.result()`` pumps to completion; ``stream.cancel()`` frees the
    request's slot and KV state mid-flight.
    """

    def __init__(self, engine, request: GenerationRequest,
                 on_token: Optional[Callable[[int, int], None]] = None):
        self._engine = engine
        self.request = request
        self.on_token = on_token
        self.tokens: list[int] = []     # everything emitted so far
        self._pending: deque[int] = deque()   # emitted, not yet iterated
        self.finished = False

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finish_reason(self) -> Optional[str]:
        return self.request.finish_reason

    # ------------------------------------------------- engine-facing hooks
    def _push(self, token: int) -> None:
        self.tokens.append(token)
        self._pending.append(token)
        if self.on_token is not None:
            self.on_token(self.request.rid, token)

    def _finish(self) -> None:
        self.finished = True

    # ---------------------------------------------------------- user side
    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        while not self._pending:
            if self.finished:
                raise StopIteration
            if not self._engine.scheduler.has_work:
                raise RuntimeError(          # engine lost this request: bug
                    f"request {self.rid} unfinished but engine is drained")
            self._engine.engine_step()
        return self._pending.popleft()

    def result(self) -> GenerationResult:
        """Pump the engine until this request finishes."""
        while not self.finished:
            if not self._engine.scheduler.has_work:
                raise RuntimeError(
                    f"request {self.rid} unfinished but engine is drained")
            self._engine.engine_step()
        return self.request.result()

    def cancel(self) -> bool:
        return self._engine.cancel(self.rid)


# ----------------------------------------------------------------- sampling
def sample_token(logits, seed, step, temperature, top_k, top_p):
    """Sample one token id from a (vocab,) logits row.

    ``temperature <= 0`` returns the exact raw-logits argmax (the PRNG path
    is computed-and-discarded under ``where``, never observed), so greedy
    requests are bit-identical to the legacy argmax engine. Otherwise:
    temperature scaling → top-k mask → top-p (nucleus) mask → categorical
    draw with key ``fold_in(PRNGKey(seed), step)``. All masks keep at least
    the argmax, so the draw is always over a non-empty support.
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits).astype(jnp.int32)
    vocab = logits.shape[-1]
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k: mask everything below the k-th largest (k<=0 disables)
    k = jnp.where(top_k > 0, top_k, vocab)
    desc = -jnp.sort(-scaled)
    kth = desc[jnp.clip(k - 1, 0, vocab - 1)]
    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    # top-p: smallest sorted prefix with cumulative probability >= top_p
    # (a token survives iff the mass STRICTLY before it is < top_p, so the
    # argmax always survives; ties at the threshold prob are all kept)
    probs = jax.nn.softmax(scaled)
    psort = -jnp.sort(-probs)
    keep = (jnp.cumsum(psort) - psort) < top_p
    thresh = jnp.min(jnp.where(keep, psort, jnp.inf))
    scaled = jnp.where(probs < thresh, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


#: Batched sampler: (B, vocab) logits + per-slot (seed, step, temperature,
#: top_k, top_p) vectors → (B,) token ids. Each slot draws from its own
#: request-derived key — determinism is per request, not per batch.
sample_batch = jax.vmap(sample_token, in_axes=(0, 0, 0, 0, 0, 0))
