"""Paged KV block pool: ONE block-table memory subsystem for serving slots,
shared prefixes and copy-on-write sampling forks (DESIGN.md §15).

``SlotKVCache`` allocates dense ``slots × max_len`` buffers, so every request
pays worst-case memory whether it uses it or not — the ~7x byte win of int4
KV rows (DESIGN.md §8) never becomes a capacity win. This module replaces
dense preallocation with a vLLM-style paged layout:

* **BlockPool** — the physical store: per-buffer-key device arrays shaped
  ``(L, num_blocks, block, ...)`` in the plan's KV precision (``kv_pack``
  layouts, ``PREFIX_BLOCK``-token blocks), plus host-side free list,
  per-block refcounts, per-request block tables and a digest-keyed prefix
  registry. ONE byte budget sizes the pool and drives both admission (a
  request admits only if its worst-case block need fits) and eviction (LRU
  over refcount-0 registry blocks). The registry absorbs
  ``prefix_cache.py``'s role: a prefix hit attaches resident blocks by
  REFERENCE (refcount++) instead of copying rows into a slot.
* **PagedKVCache** — the engine-facing slot view: per-slot block tables and
  host cursors. ``gather_state()`` materializes a dense-shaped
  ``(L, slots, max_len, ...)`` cache view by one ``jnp.take`` over the block
  axis, which feeds the engine's UNCHANGED jitted step; ``append_from``
  extracts each active slot's newly written row and scatters it to its
  (block, offset) cursor.

Bit-identity with the dense layout is by construction, not luck: a slot's
gathered view equals the dense slot buffer at every position ``< len`` (the
same values were written by the same jitted computations), and every
position ``>= len`` — including rows surfaced by clamp-gathered
out-of-range table entries — is replaced by ``NEG_INF`` before the softmax
in both the Pallas kernel and the jnp reference path, so garbage rows
contribute exact zeros to the attention output. The paged engine therefore
reuses the SAME compiled decode step as the dense engine and produces
byte-identical token streams.

Copy-on-write fork (``SamplingParams.n > 1``): samples of one prompt share
the full prompt blocks by reference; each sample owns its partial tail
block and decode blocks privately, so divergent generations never write
into shared memory. Shared blocks are only ever written once (at prefill,
before sharing), which is what makes attach-by-reference safe.

Host bookkeeping is authoritative: the pool tracks per-slot lengths itself
(the jitted step increments the gathered state's ``len`` for every slot,
active or not, and that state is discarded after the append extract).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..kernels.kv_pack import (kv_buffer_keys, kv_code_dtype, kv_code_shape,
                               kv_row_bytes, quantize_kv)
from .prefix_cache import HASH_SEED, PREFIX_BLOCK, rolling_hash

__all__ = ["BlockPool", "PagedKVCache", "blocks_needed"]


def blocks_needed(prompt_len: int, max_new_tokens: int,
                  block: int = PREFIX_BLOCK) -> int:
    """Worst-case block demand of a request: every prompt and generated
    token, rounded up to whole blocks. Admission reserves this much so a
    request can NEVER run out of KV memory mid-decode — the paged analogue
    of the dense layout's up-front ``max_len`` row reservation."""
    return -(-(prompt_len + max_new_tokens) // block)


def _take_row(state, key, row):
    """(L, n, S, ...) batch-N cache buffer -> row ``row``: (L, S, ...)."""
    return jax.lax.dynamic_index_in_dim(state[key], row, 1, keepdims=False)


def _block_shape(rows, nb: int, block: int):
    return rows.reshape(rows.shape[0], nb, block, *rows.shape[2:])


@functools.partial(jax.jit, static_argnames=("keys",))
def _gather_state(bufs, tables, lengths, keys: tuple):
    """Block tables -> a dense-shaped cache view.

    tables: (slots, nb) int32 block indices; out-of-range entries (the
    pool's ``num_blocks`` sentinel) CLAMP to the last resident block
    (``mode='clip'`` — jnp.take's default fill mode would inject NaN, and
    ``0 * NaN`` survives the post-softmax matmul even for fully-masked
    positions). Clamped entries surface arbitrary resident rows — safe
    because every position >= the slot's length is masked pre-softmax."""
    out = {}
    for key in keys:
        g = jnp.take(bufs[key], tables, axis=1,
                     mode="clip")                     # (L, slots, nb, B, ...)
        out[key] = g.reshape(g.shape[0], g.shape[1], g.shape[2] * g.shape[3],
                             *g.shape[4:])
    out["len"] = lengths
    return out


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("keys",))
def _scatter_new_rows(bufs, state, tb, off, cursors, keys: tuple):
    """Write each slot's newly appended row (at index ``cursors[s]`` of the
    gathered post-step state) to pool position ``(tb[s], off[s])``. Inactive
    slots carry an out-of-range ``tb`` and their writes drop."""
    out = {}
    for key in keys:
        st = state[key]                                 # (L, slots, S, ...)
        idx = cursors.reshape(1, -1, *([1] * (st.ndim - 2)))
        row = jnp.take_along_axis(st, idx, axis=2)      # (L, slots, 1, ...)
        row = jnp.squeeze(row, axis=2)                  # (L, slots, ...)
        out[key] = bufs[key].at[:, tb, off].set(row, mode="drop")
    return out


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("bits", "lo", "nb", "block", "keys"))
def _write_fp_blocks(bufs, pstate, row, ids, *, bits: int, lo: int, nb: int,
                     block: int, keys: tuple):
    """Quantize-on-insert from the fp batch-N prefill cache: blocks
    ``[lo, lo+nb)`` of row ``row`` land on pool blocks ``ids``. The FULL
    bucket row quantizes in one call (per-(token, head) scales make the
    result row-independent, so the sliced blocks are bitwise identical to
    the dense path's ``_insert_quant``)."""
    if bits in (8, 4):
        kq, ks = quantize_kv(_take_row(pstate, "k", row), bits)
        vq, vs = quantize_kv(_take_row(pstate, "v", row), bits)
        rows = {"k_q": kq, "v_q": vq, "k_scale": ks, "v_scale": vs}
    else:
        rows = {"k": _take_row(pstate, "k", row),
                "v": _take_row(pstate, "v", row)}
    out = {}
    for key in keys:
        r = rows[key][:, lo * block:(lo + nb) * block]
        out[key] = bufs[key].at[:, ids].set(_block_shape(r, nb, block))
    return out


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("nb", "block", "keys"))
def _write_state_blocks(bufs, state, row, start, ids, *, nb: int, block: int,
                        keys: tuple):
    """Direct same-precision copy from a plan-precision scratch cache (the
    block-chunked prefix-prefill path): token rows ``[start, start+nb*B)``
    of row ``row`` land on pool blocks ``ids`` — no requantization."""
    out = {}
    for key in keys:
        r = _take_row(state, key, row)
        r = jax.lax.dynamic_slice_in_dim(r, start, nb * block, axis=1)
        out[key] = bufs[key].at[:, ids].set(_block_shape(r, nb, block))
    return out


@functools.partial(jax.jit, static_argnames=("keys",))
def _gather_blocks(bufs, ids, keys: tuple):
    """Resident blocks ``ids`` -> contiguous (L, len(ids)*block, ...) rows
    per buffer key (the prefix-restore gather; stays on device)."""
    out = {}
    for key in keys:
        g = jnp.take(bufs[key], ids, axis=1)            # (L, nb, B, ...)
        out[key] = g.reshape(g.shape[0], g.shape[1] * g.shape[2],
                             *g.shape[3:])
    return out


class BlockPool:
    """Refcounted block-table allocator over quantized KV device blocks.

    One pool = one byte budget = ``num_blocks`` physical blocks. Every
    block is in exactly one of three states:

    * **free** — on the free list, refcount 0, not in the registry;
    * **held** — refcount >= 1: reachable from one or more live request
      tables (a block in two tables is always in ``shared`` — registry
      residents attached by reference, or fork-shared prompt blocks);
    * **resident** — refcount 0 but registered under a prefix digest:
      evictable, LRU-ordered (deepest chain blocks evict first).

    All mutation is host-side bookkeeping plus jitted donated writes into
    the device buffers; the pool is single-threaded like the engine.
    """

    def __init__(self, cfg: ModelConfig, budget_bytes: int, *,
                 dtype=jnp.float32, kv_bits: int = 16,
                 block: int = PREFIX_BLOCK, mesh=None):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.cfg = cfg
        self.block = int(block)
        self.kv_bits = int(kv_bits)
        self.dtype = dtype
        L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        fp_bytes = jnp.dtype(dtype).itemsize
        self.block_nbytes = self.block * L * kv_row_bytes(
            Hkv, hd, self.kv_bits, fp_bytes=fp_bytes)
        self.num_blocks = int(budget_bytes) // self.block_nbytes
        if self.num_blocks < 1:
            raise ValueError(
                f"kv budget {budget_bytes} B < one {self.block}-token block "
                f"({self.block_nbytes} B at kv_bits={self.kv_bits})")
        self.budget_bytes = int(budget_bytes)
        self.keys = kv_buffer_keys(self.kv_bits)
        NB = self.num_blocks
        if self.kv_bits in (8, 4):
            dhp = kv_code_shape(hd, self.kv_bits)
            cdt = kv_code_dtype(self.kv_bits)
            self.bufs = {
                "k_q": jnp.zeros((L, NB, self.block, Hkv, dhp), cdt),
                "v_q": jnp.zeros((L, NB, self.block, Hkv, dhp), cdt),
                "k_scale": jnp.zeros((L, NB, self.block, Hkv), jnp.float32),
                "v_scale": jnp.zeros((L, NB, self.block, Hkv), jnp.float32)}
        else:
            self.bufs = {
                "k": jnp.zeros((L, NB, self.block, Hkv, hd), dtype),
                "v": jnp.zeros((L, NB, self.block, Hkv, hd), dtype)}
        if mesh is not None:
            # tensor-parallel serving (DESIGN.md §16): KV heads partition
            # over "model"; the block tables below are host-side numpy, so
            # the indirection layer is replicated by construction.
            from ..distributed.sharding import (place_serving,
                                                serving_state_specs)
            self.bufs = place_serving(
                self.bufs, mesh, serving_state_specs(self.bufs, mesh))
        # host structures; allocation order is deterministic (ascending ids)
        self._free: list[int] = list(range(NB - 1, -1, -1))
        self.refs = np.zeros(NB, np.int64)
        self._tables: dict[int, list[int]] = {}       # rid -> block ids
        self.shared: set[int] = set()                 # multi-ref-legal blocks
        # prefix registry: chained digest -> resident block (LRU order);
        # reverse map + per-digest tokens for the defense-in-depth check
        self._registry: "OrderedDict[bytes, int]" = OrderedDict()
        self._digest_of: dict[int, bytes] = {}
        self._tokens: dict[bytes, np.ndarray] = {}
        # counters (host ints, never grow)
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0
        self.evictions = 0
        self.cow_forks = 0
        self.prefix_attached = 0      # blocks attached by reference, total

    # --------------------------------------------------------- allocation
    def available(self) -> int:
        """Blocks an admission decision may count on: free now, or
        evictable (refcount-0 registry residents)."""
        evictable = sum(1 for b in self._registry.values()
                        if self.refs[b] == 0)
        return len(self._free) + evictable

    def _evict_one(self) -> bool:
        """Pop the least-recently-used refcount-0 registry block back onto
        the free list. Pinned blocks (refcount > 0: in-flight requests, or
        the publisher itself) are never evicted."""
        victim = next((d for d, b in self._registry.items()
                       if self.refs[b] == 0), None)
        if victim is None:
            return False
        b = self._registry.pop(victim)
        del self._digest_of[b]
        del self._tokens[victim]
        self.shared.discard(b)
        self._free.append(b)
        self.evictions += 1
        return True

    def alloc(self, rid: int, n: int) -> list[int]:
        """Allocate ``n`` private blocks for request ``rid`` (evicting
        resident prefix blocks as needed). The engine's admission check
        (``blocks_needed`` vs ``available``) makes failure a logic error,
        not a runtime condition."""
        ids = []
        for _ in range(n):
            if not self._free and not self._evict_one():
                raise RuntimeError(
                    f"BlockPool exhausted: {self.num_blocks} blocks, "
                    f"{len(self._tables)} live tables — admission gating "
                    "should have prevented this")
            b = self._free.pop()
            self.refs[b] += 1
            ids.append(b)
        if ids:
            self._tables.setdefault(rid, []).extend(ids)
        return ids

    def attach(self, rid: int, ids) -> None:
        """Attach already-written blocks to ``rid`` BY REFERENCE (prefix
        hits, copy-on-write fork shares): refcount++ per block, appended to
        the request's table in sequence order. Never copies rows."""
        ids = list(ids)
        for b in ids:
            self.refs[b] += 1
        self.shared.update(ids)
        if ids:
            self._tables.setdefault(rid, []).extend(ids)

    def release(self, rid: int) -> None:
        """Drop every reference request ``rid`` holds. Blocks reaching
        refcount 0 return to the free list unless registry-resident (those
        stay evictable under LRU)."""
        for b in self._tables.pop(rid, ()):  # idempotent: second call no-ops
            self.refs[b] -= 1
            if self.refs[b] == 0 and b not in self._digest_of:
                self.shared.discard(b)
                self._free.append(b)

    def table(self, rid: int) -> list[int]:
        return self._tables.get(rid, [])

    # ------------------------------------------------------------- prefix
    def match(self, prompt) -> tuple[int, list[int]]:
        """Longest registry-resident block-aligned prefix of ``prompt``,
        capped at ``len(prompt) - 1`` (the last token must be computed for
        first-output logits — same contract as ``PrefixCache.match``).
        Returns ``(m, block_ids)``; the caller must ``attach`` the ids in
        the same engine round (nothing else runs in between — the pool is
        single-threaded), which is what pins them against eviction."""
        B = self.block
        h = HASH_SEED
        walked: list[bytes] = []
        ids: list[int] = []
        m = 0
        j = 0
        while (j + 1) * B <= len(prompt) - 1:
            blk = np.asarray(prompt[j * B:(j + 1) * B], np.int32)
            h = rolling_hash(h, blk)
            b = self._registry.get(h)
            if b is None or not np.array_equal(self._tokens[h], blk):
                break                      # first miss (or hash collision)
            walked.append(h)
            ids.append(b)
            m = (j + 1) * B
            j += 1
        # LRU touch DEEPEST-FIRST so chain tails evict before their roots:
        # a chain broken in the middle strands its unreachable tail at the
        # cold end of the LRU instead of pinning it behind hot roots.
        for d in reversed(walked):
            self._registry.move_to_end(d)
        if m:
            self.hits += 1
            self.prefix_attached += len(ids)
        else:
            self.misses += 1
        self.tokens_reused += m
        return m, ids

    def gather_rows(self, ids) -> dict:
        """Resident blocks -> contiguous (L, len(ids)*block, ...) device
        rows per buffer key (prefix restore into the prefill scratch)."""
        return _gather_blocks(self.bufs, jnp.asarray(ids, jnp.int32),
                              self.keys)

    def publish(self, rid: int, prompt, upto: int) -> int:
        """Register request ``rid``'s own full prompt blocks covering
        ``prompt[:upto]`` under their chain digests — the paged analogue of
        ``PrefixCache.insert``, with NO row copy: the request's blocks
        simply become registry residents (shared, evictable once every
        holder releases). Returns blocks newly registered."""
        B = self.block
        table = self._tables.get(rid, [])
        h = HASH_SEED
        walked: list[bytes] = []
        added = 0
        for j in range(upto // B):
            blk = np.asarray(prompt[j * B:(j + 1) * B], np.int32)
            h = rolling_hash(h, blk)
            existing = self._registry.get(h)
            if existing is not None:
                if not np.array_equal(self._tokens[h], blk):
                    break       # digest collision: stop publishing the chain
                walked.append(h)
                continue
            if j >= len(table):
                break
            b = table[j]
            if b in self._digest_of:        # already published under another
                break                       # chain (shared fork blocks)
            self._registry[h] = b
            self._digest_of[b] = h
            self._tokens[h] = blk
            self.shared.add(b)
            walked.append(h)
            added += 1
        for d in reversed(walked):
            self._registry.move_to_end(d)
        return added

    # -------------------------------------------------------------- stats
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def stats(self) -> dict:
        """KV memory gauges (ServeMetrics surfaces these — DESIGN.md §15)."""
        lookups = self.hits + self.misses
        return {
            "blocks_total": self.num_blocks,
            "blocks_free": len(self._free),
            "blocks_in_use": self.blocks_in_use(),
            "kv_bytes_in_use": self.blocks_in_use() * self.block_nbytes,
            "budget_bytes": self.budget_bytes,
            "block_bytes": self.block_nbytes,
            "prefix_blocks": len(self._registry),
            "prefix_attached": self.prefix_attached,
            "cow_forks": self.cow_forks,
            "evictions": self.evictions,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "tokens_reused": self.tokens_reused,
        }


class PagedKVCache:
    """Engine-facing slot view over a :class:`BlockPool`.

    Keeps per-slot block tables (padded with the pool's ``num_blocks``
    sentinel — gathers clamp, writes drop) and HOST-side cursors (the
    authoritative per-slot lengths; the gathered state's device ``len`` is
    derived from them every step and discarded after).
    """

    def __init__(self, pool: BlockPool, slots: int, max_len: int):
        if max_len % pool.block:
            raise ValueError(
                f"paged KV needs max_len % {pool.block} == 0, got {max_len}")
        self.pool = pool
        self.slots = slots
        self.max_len = max_len
        self.kv_bits = pool.kv_bits
        self.nb_max = max_len // pool.block
        self._tables = np.full((slots, self.nb_max), pool.num_blocks,
                               np.int32)
        self._nb = np.zeros(slots, np.int32)       # entries used per slot
        self._lengths = np.zeros(slots, np.int32)
        self._rids: list[Optional[int]] = [None] * slots

    # ------------------------------------------------------------- slots
    def open_slot(self, slot: int, rid: int) -> None:
        """Bind ``rid`` to ``slot`` with an empty table (prefill fills it)."""
        self.release_slot(slot)                    # belt and braces
        self._rids[slot] = rid

    def extend_table(self, slot: int, ids) -> None:
        n = len(ids)
        if n:
            at = int(self._nb[slot])
            self._tables[slot, at:at + n] = ids
            self._nb[slot] = at + n

    def set_length(self, slot: int, length: int) -> None:
        self._lengths[slot] = length

    def block_ids(self, slot: int) -> list[int]:
        return [int(b) for b in self._tables[slot, :int(self._nb[slot])]]

    def release_slot(self, slot: int) -> None:
        """Return every block reference the slot's request holds (request
        finished, cancelled, or the slot is being rebound). Idempotent."""
        rid = self._rids[slot]
        if rid is not None:
            self.pool.release(rid)
            self._rids[slot] = None
        self._tables[slot] = self.pool.num_blocks
        self._nb[slot] = 0
        self._lengths[slot] = 0

    # engine-compat alias (cancel() calls kv.reset_slot on both layouts)
    reset_slot = release_slot

    def lengths(self) -> np.ndarray:
        return self._lengths.copy()

    # ------------------------------------------------------------ decode
    def gather_state(self) -> dict:
        """Dense-shaped (L, slots, max_len, ...) view for the engine's ONE
        jitted step — same shapes, same compiled code as the dense layout."""
        state = _gather_state(self.pool.bufs, jnp.asarray(self._tables),
                              jnp.asarray(self._lengths), self.pool.keys)
        return state

    def append_from(self, state, active) -> None:
        """Extract each active slot's newly appended row (written by the
        step at that slot's old cursor) out of the post-step gathered state
        and scatter it to the pool block the table maps that position to.
        Inactive slots target the out-of-range sentinel and drop. Advances
        the host cursors afterwards."""
        NB = self.pool.num_blocks
        B = self.pool.block
        tb = np.full(self.slots, NB, np.int32)
        off = np.zeros(self.slots, np.int32)
        for s in active:
            ln = int(self._lengths[s])
            tb[s] = self._tables[s, ln // B]
            off[s] = ln % B
        self.pool.bufs = _scatter_new_rows(
            self.pool.bufs, state, jnp.asarray(tb), jnp.asarray(off),
            jnp.asarray(self._lengths), self.pool.keys)
        for s in active:
            self._lengths[s] += 1

    # ----------------------------------------------------------- prefill
    def write_fp_blocks(self, ids, pstate, row: int, lo: int,
                        nb: int) -> None:
        """Blocks ``[lo, lo+nb)`` of fp prefill row ``row`` -> pool blocks
        ``ids`` (quantize-on-insert at kv_bits < 16)."""
        assert len(ids) == nb, (ids, nb)
        self.pool.bufs = _write_fp_blocks(
            self.pool.bufs, pstate, jnp.int32(row),
            jnp.asarray(ids, jnp.int32), bits=self.kv_bits, lo=lo, nb=nb,
            block=self.pool.block, keys=self.pool.keys)

    def write_state_blocks(self, ids, state, row: int, start: int,
                           nb: int) -> None:
        """Token rows ``[start, start+nb*B)`` of plan-precision scratch row
        ``row`` -> pool blocks ``ids`` (the prefix-chunked path: no
        requantization)."""
        assert len(ids) == nb, (ids, nb)
        self.pool.bufs = _write_state_blocks(
            self.pool.bufs, state, jnp.int32(row), jnp.int32(start),
            jnp.asarray(ids, jnp.int32), nb=nb, block=self.pool.block,
            keys=self.pool.keys)
