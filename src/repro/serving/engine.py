"""Serving engine: prefill/decode-separated step loop (DESIGN.md §7) behind
the streaming generation API (DESIGN.md §10).

Two-phase execution over a deployed model (``repro.deploy.DeployedModel``, or
a raw params tree plus its ``ExecutionPlan``):

* **prefill** — a newly admitted request's whole prompt runs in ONE forward
  (batch 1, prompt padded to a power-of-two bucket to bound recompiles); the
  resulting per-layer KV rows are scattered into the request's slot and the
  first output token falls out of the same pass.
* **decode** — one token per step for every occupied slot, batched across the
  slot table with per-slot cache cursors (kv_cache.SlotKVCache).

Both phases sample through ONE jitted step: the legacy per-batch ``argmax``
is the ``temperature=0`` case of ``api.sample_batch``, which threads per-slot
(seed, step, temperature, top_k, top_p) vectors alongside the decode state so
a request's tokens are a function of (prompt, seed) only — never of which
other requests share the batch.

``engine_step()`` is the public pump: one admit → prefill → batched-decode
round, returning the ``(rid, token)`` pairs it emitted (``TokenStream``
handles are fed from inside it). ``run_until_drained`` is a loop over it and
raises when ``max_steps`` strands work. ``cancel(rid)`` frees a queued entry
or an occupied slot (KV state reset) mid-flight.

Everything configuration-shaped — segments, kernel selection, KV precision,
prefill mode, decode dtype, default sampling — comes from the plan; the
engine itself only owns slots, max_len and the step loop. Families without a
{'k','v','len'} decode cache (xlstm, hybrid, encdec) run
``prefill_mode='token'``: the seed semantics with a shared cursor.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..deploy import DeployedModel, ExecutionPlan
from ..models import api as model_api
from .api import (GenerationRequest, SamplingParams, TokenStream,
                  sample_batch, sample_token)
from .kv_cache import SlotKVCache
from .metrics import ServeMetrics
from .scheduler import Request, Scheduler  # noqa: F401  (compat re-export)


def _bucket_for(plen: int, max_len: int, min_bucket: int = 8) -> int:
    b = min_bucket
    while b < plen:
        b *= 2
    return min(b, max_len)


class ServingEngine:
    """Continuous-batching engine over the deployed quantized model.

    ``model`` is a :class:`DeployedModel` (plan included), or a raw params
    tree with ``plan`` passed explicitly. ``max_queue`` bounds the pending
    queue (``submit`` raises :class:`QueueFullError` past it).
    """

    def __init__(self, model, plan: Optional[ExecutionPlan] = None, *,
                 slots: int = 8, max_len: int = 512,
                 max_queue: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None):
        if isinstance(model, DeployedModel):
            if plan is not None and plan != model.plan:
                raise ValueError(
                    "pass either a DeployedModel (plan included) or raw "
                    "params + plan, not a conflicting pair")
            params, plan = model.params, model.plan
        else:
            params = model
            if plan is None:
                raise TypeError("raw params need an ExecutionPlan; build one "
                                "with repro.deploy.ExecutionPlan.build")
        self.plan = plan
        self.cfg = cfg = plan.cfg
        self.segments = segments = plan.segments
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.dtype = plan.jnp_dtype           # the ONE serving decode dtype
        self.kv_bits = plan.kv_bits
        self.prefill_mode = plan.prefill_mode
        self.default_sampling = (plan.default_sampling
                                 if plan.default_sampling is not None
                                 else SamplingParams())
        self.scheduler = Scheduler(slots, max_queue=max_queue)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.generated: list[list[int]] = [[] for _ in range(slots)]
        self._streams: dict[int, TokenStream] = {}
        self._events: list[tuple[int, int]] = []
        # per-slot sampling state, threaded into the jitted step alongside
        # the decode state (DESIGN.md §10): seed/temperature/top_k/top_p are
        # set at admit; the step index is the slot's generated-token count.
        self._seed = np.zeros(slots, np.int32)
        self._temp = np.zeros(slots, np.float32)
        self._topk = np.zeros(slots, np.int32)
        self._topp = np.ones(slots, np.float32)

        if self.prefill_mode == "chunked":
            self.kv = SlotKVCache.from_plan(plan, slots, max_len)
            self.state = None
            self._prefill_fns: dict[int, callable] = {}
        else:
            self.kv = None
            self.state = plan.decode_state(slots, max_len)
            self.pos = np.zeros(slots, np.int32)   # per-slot prompt cursor

        def step(params, state, tokens, seeds, steps, temps, top_ks, top_ps):
            logits, new_state, _, _ = model_api.forward(
                params, cfg, segments, state=state, tokens=tokens)
            toks = sample_batch(logits[:, -1], seeds, steps, temps,
                                top_ks, top_ps)
            return toks, new_state

        self._step = jax.jit(step, donate_argnums=(1,))
        self._sample1 = jax.jit(sample_token)   # prefill's first token

    # ------------------------------------------------------------------ API
    def submit(self, req: GenerationRequest, *,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> TokenStream:
        """Validate + enqueue; returns the request's :class:`TokenStream`
        (iterate it, or pass ``on_token`` for the callback form). Malformed
        requests are rejected HERE, for both prefill modes — by decode time
        the bad prompt would have been scattered into the cache (or indexed
        at [-1]) already."""
        self.scheduler.assign_id(req)      # so rejections carry a real rid
        plen = len(req.prompt)
        if plen <= 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if plen + req.max_new_tokens > self.max_len and \
                self.cfg.family != "xlstm":
            # past max_len the cache writes clamp or drop silently — decode
            # would keep emitting tokens that cannot see recent context.
            # (xlstm state is recurrent: no positional cache to overflow.
            # Token mode's shared cursor makes this necessary, not
            # sufficient — inherited seed semantics.)
            raise ValueError(
                f"request {req.rid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_len "
                f"({self.max_len})")
        req.sampling = SamplingParams.resolve(
            req.sampling if req.sampling is not None
            else self.default_sampling)
        stream = TokenStream(self, req, on_token=on_token)
        self._streams[req.rid] = stream
        try:
            self.scheduler.submit(req)     # may raise QueueFullError
        except Exception:
            self._streams.pop(req.rid, None)
            raise
        return stream

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or mid-flight request. An occupied slot is freed
        immediately — its KV rows are zeroed and its cursor rewound — so the
        next ``engine_step`` can admit queued work into it. Tokens already
        generated stay on ``req.out``; ``finish_reason`` becomes
        ``'cancelled'``. Returns False when ``rid`` is unknown or already
        finished."""
        req = self.scheduler.cancel(rid)
        if req is not None:                      # still queued: never ran
            self._finalize_unslotted(req, "cancelled")
            return True
        for s, req in enumerate(self.scheduler.active):
            if req is not None and req.rid == rid:
                req.out = np.array(self.generated[s], np.int32)
                req.finish_reason = "cancelled"
                self.scheduler.complete(s)
                if self.kv is not None:
                    self.kv.reset_slot(s)        # free the KV state now
                self._close_stream(req)
                return True
        return False

    def pop_done(self) -> list[GenerationRequest]:
        """Drain completed requests (see ``Scheduler.pop_done``)."""
        return self.scheduler.pop_done()

    @property
    def done(self) -> list[GenerationRequest]:
        return self.scheduler.done

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def active(self):
        return self.scheduler.active

    def run_until_drained(self, max_steps: int = 10000) -> int:
        """Pump ``engine_step`` until no work remains; raises RuntimeError
        instead of silently stranding requests when ``max_steps`` hits."""
        steps = 0
        while self.scheduler.has_work:
            if steps >= max_steps:
                q = self.scheduler.queue_depth
                a = self.scheduler.num_active
                raise RuntimeError(
                    f"run_until_drained: hit max_steps={max_steps} with "
                    f"{q + a} request(s) stranded ({q} queued, {a} active)")
            self.engine_step()
            steps += 1
        return steps

    def engine_step(self) -> list[tuple[int, int]]:
        """The public pump: one admit → prefill → batched-decode round.
        Returns the ``(rid, token)`` pairs emitted this step (streams and
        callbacks are fed from inside)."""
        self._events = []
        if self.prefill_mode == "chunked":
            self._chunked_step()
        else:
            self._token_step()
        for req in self.scheduler.pop_shed():
            self._finalize_unslotted(req, "shed")
        return self._events

    # ------------------------------------------------------------ lifecycle
    def _admit(self) -> list[tuple[int, "GenerationRequest"]]:
        """Scheduler admit + per-slot sampling-state install + queue-wait
        metric."""
        placed = self.scheduler.admit()
        for s, req in placed:
            sp = req.sampling
            self._seed[s] = np.int32(sp.seed & 0x7FFFFFFF)
            self._temp[s] = sp.temperature
            self._topk[s] = sp.top_k
            self._topp[s] = sp.top_p
            if req.queue_wait_s is not None:
                self.metrics.record_wait("queue_wait", req.queue_wait_s)
        return placed

    def _emit(self, req: GenerationRequest, token: int) -> None:
        if req.first_token_t is None:
            req.first_token_t = time.monotonic()
            if req.ttft_s is not None:
                self.metrics.record_wait("ttft", req.ttft_s)
        stream = self._streams.get(req.rid)
        if stream is not None:
            stream._push(token)
        self._events.append((req.rid, token))

    def _close_stream(self, req: GenerationRequest) -> None:
        stream = self._streams.pop(req.rid, None)
        if stream is not None:
            stream._finish()

    def _finalize_unslotted(self, req: GenerationRequest,
                            reason: str) -> None:
        """Finish a request that never occupied a slot (queued-cancel or
        deadline shed): empty output, straight to done."""
        req.out = np.zeros(0, np.int32)
        req.finish_reason = reason
        self.scheduler.done.append(req)
        self._close_stream(req)

    def _maybe_complete(self, slot: int, req: GenerationRequest) -> None:
        toks = self.generated[slot]
        if toks and toks[-1] in req.stop_tokens:
            self._complete(slot, req, "stop")    # stop token stays in out
        elif len(toks) >= req.max_new_tokens:
            self._complete(slot, req, "length")

    def _complete(self, slot: int, req: GenerationRequest,
                  reason: str) -> None:
        req.out = np.array(self.generated[slot][:req.max_new_tokens],
                           np.int32)
        req.finish_reason = reason
        self.scheduler.complete(slot)
        self._close_stream(req)

    # ------------------------------------------------------------- chunked
    def _prefill_fn(self, bucket: int):
        """Batch-1 full-prompt forward, compiled once per bucket size."""
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            cfg, segments, plan = self.cfg, self.segments, self.plan

            def pf(params, tokens):
                # prefill always runs on the fp cache regardless of
                # plan.kv_bits; quantization happens on slot insert
                st = plan.decode_state(1, bucket, kv_bits=16)
                logits, st2, _, _ = model_api.forward(
                    params, cfg, segments, state=st, tokens=tokens)
                return logits, st2

            fn = self._prefill_fns[bucket] = jax.jit(pf)
        return fn

    def _prefill_into_slot(self, slot: int, req: GenerationRequest) -> None:
        plen = len(req.prompt)
        assert plen > 0, f"request {req.rid}: empty prompt past submit()"
        bucket = _bucket_for(plen, self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        t0 = time.perf_counter()
        logits, pstate = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks))
        first = int(np.asarray(self._sample1(
            logits[0, plen - 1], self._seed[slot], np.int32(0),
            self._temp[slot], self._topk[slot], self._topp[slot])))
        self.kv.reset_slot(slot)
        self.kv.insert_prefill(slot, pstate, plen, bucket)
        self.metrics.record("prefill", time.perf_counter() - t0, plen)
        self.generated[slot] = [first]
        self._emit(req, first)
        if self.scheduler.active[slot] is req:   # callback may have cancelled
            self._maybe_complete(slot, req)

    def _gen_steps(self) -> np.ndarray:
        """Per-slot index of the NEXT generated token (the sampling step fed
        to ``fold_in``), so token i of a request always draws from the same
        key regardless of batch composition."""
        return np.array([len(self.generated[s]) for s in range(self.slots)],
                        np.int32)

    def _chunked_step(self) -> None:
        for s, req in self._admit():
            if self.scheduler.active[s] is not req:
                continue   # an earlier prefill's on_token callback cancelled
            self._prefill_into_slot(s, req)
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.generated[s][-1]
        t0 = time.perf_counter()
        next_tok, self.kv.state = self._step(
            self.params, self.kv.state, jnp.asarray(toks),
            self._seed, self._gen_steps(), self._temp, self._topk,
            self._topp)
        next_tok = np.asarray(next_tok)
        self.metrics.record("decode", time.perf_counter() - t0, len(active))
        for s in active:
            req = self.scheduler.active[s]
            if req is None:    # freed mid-step by an on_token cancel()
                continue
            self.generated[s].append(int(next_tok[s]))
            self._emit(req, int(next_tok[s]))
            if self.scheduler.active[s] is req:   # ... or a self-cancel
                self._maybe_complete(s, req)

    # --------------------------------------------------------------- token
    def _token_step(self) -> None:
        """Seed semantics: prompts fed one token per batched step (global
        cache cursor; used by families without a KV slot cache)."""
        for s, _req in self._admit():
            self.generated[s] = []
            self.pos[s] = 0
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.scheduler.active[s]
            if self.pos[s] < len(req.prompt):      # still feeding the prompt
                toks[s, 0] = req.prompt[self.pos[s]]
            else:                                  # submit() bans empty
                toks[s, 0] = self.generated[s][-1]  # prompts: always filled
        t0 = time.perf_counter()
        next_tok, self.state = self._step(
            self.params, self.state, jnp.asarray(toks),
            self._seed, self._gen_steps(), self._temp, self._topk,
            self._topp)
        next_tok = np.asarray(next_tok)
        # a slot emits a generated token this step once it has consumed its
        # last prompt token, i.e. pos >= plen - 1 before the increment
        n_decoding = sum(
            self.pos[s] >= len(self.scheduler.active[s].prompt) - 1
            for s in active)
        self.metrics.record("decode", time.perf_counter() - t0, n_decoding)
        for s in active:
            req = self.scheduler.active[s]
            if req is None:    # freed mid-step by an on_token cancel()
                continue
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                self.generated[s].append(int(next_tok[s]))
                self._emit(req, int(next_tok[s]))
                if self.scheduler.active[s] is req:   # ... or a self-cancel
                    self._maybe_complete(s, req)
