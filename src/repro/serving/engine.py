"""Serving engine: prefill/decode-separated step loop (DESIGN.md §7) behind
the streaming generation API (DESIGN.md §10), with shared-prefix KV reuse,
batched bucketed prefill (DESIGN.md §11), an optional paged KV layout —
``plan.kv_paging='paged'`` routes the slot cache through the refcounted
block pool of ``serving/block_pool.py``: byte-budgeted admission, prefix
blocks attached by reference, copy-on-write ``n>1`` forks, bit-identical
streams (DESIGN.md §15) — and prefill-only encode traffic
(DESIGN.md §14) — classify/embed/score requests that resolve in the step
that admits them, either on a mode='encoder' plan (bidirectional int4 BERT,
per-row length masking keeps bucket padding bit-exact) or interleaved with
decode traffic on a generation engine (task='score' = prompt
log-likelihood).

Two-phase execution over a deployed model (``repro.deploy.DeployedModel``, or
a raw params tree plus its ``ExecutionPlan``):

* **prefill** — admissions are grouped by (bucket, cached-prefix) and each
  group runs as ONE batch-N forward (``plan.prefill_batch`` caps N; N pads to
  a power of two so the compile-key space stays (bucket, n)). With
  ``plan.prefix_cache`` enabled, the longest cached block-aligned prefix is
  scattered into the slot — quantized codes + scales copy directly — and
  only the suffix is computed, block-chunked so the rows a cold run attends
  to are bit-equal to the rows a hit copies out of the cache.
* **decode** — one token per step for every occupied slot, batched across the
  slot table with per-slot cache cursors (kv_cache.SlotKVCache).

Both phases sample through ONE jitted step: the legacy per-batch ``argmax``
is the ``temperature=0`` case of ``api.sample_batch``, which threads per-slot
(seed, step, temperature, top_k, top_p) vectors alongside the decode state so
a request's tokens are a function of (prompt, seed) only — never of which
other requests share the batch (or the prefill group).

``engine_step()`` is the public pump: one admit → prefill → batched-decode
round, returning the ``(rid, token)`` pairs it emitted (``TokenStream``
handles are fed from inside it). ``run_until_drained`` is a loop over it and
raises when ``max_steps`` strands work. ``cancel(rid)`` frees a queued entry
or an occupied slot (KV state reset) mid-flight; every slotted exit funnels
through one finalize helper, so cancel and complete truncate output
identically.

Everything configuration-shaped — segments, kernel selection, KV precision,
prefill mode, decode dtype, default sampling, prefix/batch prefill knobs —
comes from the plan; the engine itself only owns slots, max_len and the step
loop. Families without a {'k','v','len'} decode cache (xlstm, hybrid,
encdec) run ``prefill_mode='token'``: the seed semantics with a shared
cursor, now guarded against cursor exhaustion (admission is refused until
the cursor fits the request; an idle engine resets its state instead of
silently clamping KV writes past max_len).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..deploy import DeployedModel, ExecutionPlan
from ..kernels.kv_pack import kv_buffer_keys, kv_row_bytes
from ..models import api as model_api
from ..models.bert import bert_encode, bert_pool
from .api import (GenerationRequest, SamplingParams, TokenStream,
                  sample_batch, sample_seed, sample_token)
from .block_pool import BlockPool, PagedKVCache, blocks_needed
from .clock import SYSTEM_CLOCK, Clock
from .encoder import EncodeHandle, EncodeRequest
from .kv_cache import SlotKVCache
from .metrics import ServeMetrics
from .prefix_cache import PREFIX_BLOCK, PrefixCache
from .scheduler import Request, Scheduler, group_admits  # noqa: F401 (compat)


def _bucket_for(plen: int, max_len: int, min_bucket: int = 8) -> int:
    b = min_bucket
    while b < plen:
        b *= 2
    return min(b, max_len)


def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class ServingEngine:
    """Continuous-batching engine over the deployed quantized model.

    ``model`` is a :class:`DeployedModel` (plan included), or a raw params
    tree with ``plan`` passed explicitly. ``max_queue`` bounds the pending
    queue (``submit`` raises :class:`QueueFullError` past it).
    """

    def __init__(self, model, plan: Optional[ExecutionPlan] = None, *,
                 slots: int = 8, max_len: int = 512,
                 max_queue: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None,
                 clock: Clock = SYSTEM_CLOCK,
                 tenant: Optional[str] = None,
                 kv_budget_bytes: Optional[int] = None,
                 warmup: bool = False):
        if isinstance(model, DeployedModel):
            if plan is not None and plan != model.plan:
                raise ValueError(
                    "pass either a DeployedModel (plan included) or raw "
                    "params + plan, not a conflicting pair")
            params, plan = model.params, model.plan
        else:
            params = model
            if plan is None:
                raise TypeError("raw params need an ExecutionPlan; build one "
                                "with repro.deploy.ExecutionPlan.build")
        self.plan = plan
        self.cfg = cfg = plan.cfg
        self.segments = segments = plan.segments
        # tensor-parallel serving (DESIGN.md §16): a tp>1 plan owns a
        # ("model",) mesh; weights/KV are partitioned over it. deploy()
        # already places DeployedModel params, so re-placing is a no-op
        # there — this covers the raw params + plan constructor form.
        self.mesh = plan.make_mesh()
        if self.mesh is not None:
            from ..distributed.sharding import (place_serving,
                                                serving_param_specs)
            params = place_serving(params, self.mesh,
                                   serving_param_specs(params))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.mode = plan.mode                 # "decode" | "encoder"
        self.tenant = tenant                  # metrics label (DESIGN.md §14)
        self.dtype = plan.jnp_dtype           # the ONE serving decode dtype
        self.kv_bits = plan.kv_bits
        self.prefill_mode = plan.prefill_mode
        self.prefill_batch = max(1, plan.prefill_batch)
        self.default_sampling = (plan.default_sampling
                                 if plan.default_sampling is not None
                                 else SamplingParams())
        # ONE clock for the whole serving stack (DESIGN.md §12): deadline
        # shedding, TTFT/queue-wait stamps, and step timings all read it, so
        # injecting a VirtualClock makes every timing path deterministic.
        self.clock = clock
        self.scheduler = Scheduler(slots, max_queue=max_queue, clock=clock)
        self.metrics = (metrics if metrics is not None
                        else ServeMetrics(clock=clock))
        self.generated: list[list[int]] = [[] for _ in range(slots)]
        self._streams: dict = {}              # rid -> TokenStream|EncodeHandle
        self._events: list[tuple[int, int]] = []
        # per-step work counters, reset by engine_step: the multi-tenant
        # deficit accounting and the virtual-cost model read them after
        # each pump (DESIGN.md §14).
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0
        # per-slot sampling state, threaded into the jitted step alongside
        # the decode state (DESIGN.md §10): seed/temperature/top_k/top_p are
        # set at admit; the step index is the slot's generated-token count.
        self._seed = np.zeros(slots, np.int32)
        self._temp = np.zeros(slots, np.float32)
        self._topk = np.zeros(slots, np.int32)
        self._topp = np.ones(slots, np.float32)

        self.prefix_cache: Optional[PrefixCache] = None
        self._prefix_refs: dict[int, tuple] = {}   # rid -> pinned block keys
        self._encode_fns: dict[tuple, callable] = {}
        # paged KV layout (DESIGN.md §15): plan.kv_paging='paged' routes the
        # slot cache through the refcounted block pool
        self.paged = plan.kv_paging == "paged"
        self.pool: Optional[BlockPool] = None
        self._prefix_on = False    # paged-mode prefix registry switch
        self._reserved = 0         # blocks reserved within one admit round
        self._next_fork = 0        # fork-group ids for n>1 fanout
        if kv_budget_bytes is not None and not self.paged:
            raise ValueError(
                "kv_budget_bytes applies to kv_paging='paged' plans only "
                "(the dense layout preallocates slots*max_len rows)")
        if self.mode == "encoder":
            # prefill-only: no KV retained across steps, no decode state —
            # every request resolves inside the step that admits it.
            self.kv = None
            self.state = None
        elif self.prefill_mode == "chunked":
            self.state = None
            self._prefill_fns: dict[tuple, callable] = {}
            self._chunk_fns: dict[tuple, callable] = {}
            if self.paged:
                if max_len % PREFIX_BLOCK:
                    raise ValueError(
                        f"kv_paging='paged' needs max_len % {PREFIX_BLOCK} "
                        f"== 0 (block granularity), got {max_len}")
                block_bytes = PREFIX_BLOCK * cfg.num_layers * kv_row_bytes(
                    cfg.num_kv_heads, cfg.hd, self.kv_bits,
                    fp_bytes=jnp.dtype(self.dtype).itemsize)
                if kv_budget_bytes is None:
                    # dense-equivalent default: exactly the bytes the dense
                    # layout would preallocate, so flipping kv_paging alone
                    # changes the layout, never the capacity
                    kv_budget_bytes = (slots * (max_len // PREFIX_BLOCK)
                                       * block_bytes)
                self.pool = BlockPool(cfg, kv_budget_bytes, dtype=self.dtype,
                                      kv_bits=self.kv_bits, mesh=self.mesh)
                self.kv = PagedKVCache(self.pool, slots, max_len)
                # plan.prefix_cache > 0 switches prefix reuse on; the BYTE
                # value is absorbed by the pool budget (the registry shares
                # the pool's blocks instead of owning a second store)
                self._prefix_on = plan.prefix_cache > 0
            else:
                self.kv = SlotKVCache.from_plan(plan, slots, max_len,
                                                mesh=self.mesh)
                if plan.prefix_cache:
                    self.prefix_cache = PrefixCache(plan.prefix_cache)
        else:
            self.kv = None
            self.state = self._place_state(plan.decode_state(slots, max_len))
            self.pos = np.zeros(slots, np.int32)   # per-slot prompt cursor
            self._cursor = 0   # host mirror of the SHARED token-mode cursor

        def step(params, state, tokens, seeds, steps, temps, top_ks, top_ps):
            logits, new_state, _, _ = model_api.forward(
                params, cfg, segments, state=state, tokens=tokens)
            toks = sample_batch(logits[:, -1], seeds, steps, temps,
                                top_ks, top_ps)
            return toks, new_state

        self._step = jax.jit(step, donate_argnums=(1,))
        self._sample1 = jax.jit(sample_token)   # prefill's first token
        if warmup:
            self._warmup()

    def _place_state(self, state):
        """Partition a freshly allocated decode state over the tp mesh
        (no-op at tp=1)."""
        if self.mesh is None:
            return state
        from ..distributed.sharding import place_serving, serving_state_specs
        return place_serving(state, self.mesh,
                             serving_state_specs(state, self.mesh))

    def _warmup(self) -> None:
        """Pre-populate the (bucket, n) compile-key caches before traffic
        arrives (DESIGN.md §16): every prefill/encode bucket on the ladder
        (8, 16, ... max_len doubling) times every power-of-two group size up
        to ``prefill_batch``, plus the decode step. Each jitted function is
        actually CALLED on throwaway zeros — ``lower().compile()`` would not
        populate the pjit call cache — and the decode step is warmed against
        a THROWAWAY state, never the live (donated) cache. Nothing is
        recorded in metrics: the first *real* step's latency then shows the
        steady-state cost, which is exactly what the first-vs-steady metric
        split exists to surface."""
        buckets, b = [], 8
        while b < self.max_len:
            buckets.append(b)
            b *= 2
        buckets.append(self.max_len)
        ns, n = [], 1
        while n <= self.prefill_batch:
            ns.append(n)
            n *= 2
        if self.mode == "encoder":
            for bucket in buckets:
                for n in ns:
                    self._encode_fn(bucket, n)(
                        self.params, jnp.zeros((n, bucket), jnp.int32),
                        jnp.ones(n, jnp.int32))
            return
        if self.prefill_mode != "chunked":
            # token mode: one compile key — the batched step itself; warmed
            # below with the throwaway state
            state = self._place_state(
                self.plan.decode_state(self.slots, self.max_len))
        else:
            for bucket in buckets:
                for n in ns:
                    self._prefill_fn(bucket, n)(
                        self.params, jnp.zeros((n, bucket), jnp.int32))
            if (self.paged and self._prefix_on) \
                    or self.prefix_cache is not None:
                B = self.pool.block if self.paged else self.prefix_cache.block
                for bucket in buckets:
                    S = -(-bucket // B) * B
                    for n in ns:
                        self._chunk_fn(S, n)(
                            self.params, self.plan.decode_state(n, S),
                            jnp.zeros((n, B), jnp.int32))
            if self.paged:
                # the live decode input IS a gathered view; gathering the
                # (empty, sentinel-clamped) tables warms both the gather and
                # the step on exactly the avals decode will present
                state = self.kv.gather_state()
            else:
                state = self._place_state(self.plan.decode_state(
                    self.slots, self.max_len, per_slot_len=True))
        self._step(self.params, state, jnp.zeros((self.slots, 1), jnp.int32),
                   self._seed, self._gen_steps(), self._temp, self._topk,
                   self._topp)

    # ------------------------------------------------------------------ API
    def submit(self, req: GenerationRequest, *,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> TokenStream:
        """Validate + enqueue; returns the request's :class:`TokenStream`
        (iterate it, or pass ``on_token`` for the callback form). Malformed
        requests are rejected HERE, for both prefill modes — by decode time
        the bad prompt would have been scattered into the cache (or indexed
        at [-1]) already.

        ``sampling.n > 1`` fans out into ``n`` independent child requests
        (sample ``i`` decodes with seed ``api.sample_seed(seed, i)``) and
        returns a LIST of ``n`` streams instead of one. On a paged engine
        the children share the prompt's KV blocks copy-on-write; on a dense
        engine they expand into plain slots — the streams are identical
        either way. A ``QueueFullError`` mid-fanout propagates; children
        already enqueued stay queued (cancel them by rid if unwanted)."""
        if self.mode == "encoder":
            raise ValueError(
                "this engine serves a mode='encoder' plan: no decode loop "
                "exists; submit EncodeRequests via submit_encode")
        self.scheduler.assign_id(req)      # so rejections carry a real rid
        plen = len(req.prompt)
        if plen <= 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if plen + req.max_new_tokens > self.max_len and \
                self.cfg.family != "xlstm":
            # past max_len the cache writes clamp or drop silently — decode
            # would keep emitting tokens that cannot see recent context.
            # (xlstm state is recurrent: no positional cache to overflow.
            # Token mode's shared cursor additionally gates ADMISSION on the
            # live cursor — see _token_fits — so steady-state slot refills
            # can no longer walk the cursor past max_len.)
            raise ValueError(
                f"request {req.rid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_len "
                f"({self.max_len})")
        if self.paged:
            need = blocks_needed(plen, req.max_new_tokens)
            if need > self.pool.num_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks but the "
                    f"pool budget holds {self.pool.num_blocks} total — "
                    "raise kv_budget_bytes or shrink the request")
        req.sampling = SamplingParams.resolve(
            req.sampling if req.sampling is not None
            else self.default_sampling)
        sp = req.sampling
        if sp.n > 1:
            gid = self._next_fork
            self._next_fork += 1
            streams = []
            for i in range(sp.n):
                child = dataclasses.replace(
                    req,
                    sampling=dataclasses.replace(
                        sp, n=1, seed=sample_seed(sp.seed, i)),
                    rid=-1, out=None, finish_reason=None)
                child.fork_group = gid
                child.sample_index = i
                streams.append(self.submit(child, on_token=on_token))
            return streams
        stream = TokenStream(self, req, on_token=on_token)
        self._streams[req.rid] = stream
        try:
            self.scheduler.submit(req)     # may raise QueueFullError
        except Exception:
            self._streams.pop(req.rid, None)
            raise
        return stream

    def submit_encode(self, req: EncodeRequest, *,
                      on_result: Optional[Callable[[int, object], None]] = None
                      ) -> EncodeHandle:
        """Enqueue a prefill-only request (DESIGN.md §14). Shares the
        scheduler — priority heap, bounded queue, deadline shed, cancel —
        with generation traffic; the result lands on the returned
        :class:`EncodeHandle`. Task support is family-shaped: an encoder
        plan serves classify/embed/score from its heads, while a decode
        engine serves ``score`` only (prompt log-likelihood through the
        same batched bucketed prefill path)."""
        self.scheduler.assign_id(req)      # so rejections carry a real rid
        plen = len(req.tokens)
        if plen <= 0:
            raise ValueError(f"request {req.rid}: empty input")
        if plen > self.max_len:
            raise ValueError(
                f"request {req.rid}: input ({plen}) exceeds engine max_len "
                f"({self.max_len})")
        if self.mode == "encoder":
            needs = ("classifier",) if req.task in ("classify", "score") \
                else ("pooler",)
            for head in needs:
                if head not in self.params:
                    raise ValueError(
                        f"request {req.rid}: task={req.task!r} needs a "
                        f"{head!r} head the deployed artifact does not have")
        else:
            if self.prefill_mode != "chunked":
                raise ValueError(
                    f"request {req.rid}: token-mode engines feed prompts "
                    "through a shared cursor and cannot serve prefill-only "
                    "requests")
            if req.task != "score":
                raise ValueError(
                    f"request {req.rid}: a decoder artifact serves only "
                    f"task='score' (prompt log-likelihood), got "
                    f"{req.task!r}")
        handle = EncodeHandle(self, req, on_result=on_result)
        self._streams[req.rid] = handle
        try:
            self.scheduler.submit(req)     # may raise QueueFullError
        except Exception:
            self._streams.pop(req.rid, None)
            raise
        return handle

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or mid-flight request. An occupied slot is freed
        immediately — its KV rows are zeroed and its cursor rewound — so the
        next ``engine_step`` can admit queued work into it. Tokens already
        generated stay on ``req.out`` (truncated to ``max_new_tokens``, like
        every other exit); ``finish_reason`` becomes ``'cancelled'``.
        Returns False when ``rid`` is unknown or already finished."""
        req = self.scheduler.cancel(rid)
        if req is not None:                      # still queued: never ran
            self._finalize_unslotted(req, "cancelled")
            return True
        for s, req in enumerate(self.scheduler.active):
            if req is not None and req.rid == rid:
                self._finalize_slotted(s, req, "cancelled")
                if self.kv is not None:
                    self.kv.reset_slot(s)        # free the KV state now
                return True
        return False

    def pop_done(self) -> list[GenerationRequest]:
        """Drain completed requests (see ``Scheduler.pop_done``)."""
        return self.scheduler.pop_done()

    @property
    def done(self) -> list[GenerationRequest]:
        return self.scheduler.done

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def active(self):
        return self.scheduler.active

    def run_until_drained(self, max_steps: int = 10000) -> int:
        """Pump ``engine_step`` until no work remains; raises RuntimeError
        instead of silently stranding requests when ``max_steps`` hits."""
        steps = 0
        while self.scheduler.has_work:
            if steps >= max_steps:
                q = self.scheduler.queue_depth
                a = self.scheduler.num_active
                raise RuntimeError(
                    f"run_until_drained: hit max_steps={max_steps} with "
                    f"{q + a} request(s) stranded ({q} queued, {a} active)")
            self.engine_step()
            steps += 1
        return steps

    def engine_step(self) -> list[tuple[int, int]]:
        """The public pump: one admit → prefill → batched-decode round.
        Returns the ``(rid, token)`` pairs emitted this step (streams and
        callbacks are fed from inside)."""
        self._events = []
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0
        if self.mode == "encoder":
            self._encoder_step()
        elif self.prefill_mode == "chunked":
            self._chunked_step()
        else:
            self._token_step()
        for req in self.scheduler.pop_shed():
            self._finalize_unslotted(req, "shed")
        if self.paged:
            self.metrics.update_kv(self.pool.stats())
        return self._events

    # ------------------------------------------------------------ lifecycle
    def _admit(self, fits: Optional[Callable] = None
               ) -> list[tuple[int, "GenerationRequest"]]:
        """Scheduler admit + per-slot sampling-state install + queue-wait
        metric. Clears the slot's stale token tally up front, so a cancel
        landing between admission and prefill cannot report the previous
        occupant's tokens."""
        placed = self.scheduler.admit(fits=fits)
        for s, req in placed:
            self.generated[s] = []
            sp = getattr(req, "sampling", None)  # EncodeRequests don't sample
            if sp is not None:
                self._seed[s] = np.int32(sp.seed & 0x7FFFFFFF)
                self._temp[s] = sp.temperature
                self._topk[s] = sp.top_k
                self._topp[s] = sp.top_p
            if req.queue_wait_s is not None:
                self.metrics.record_wait("queue_wait", req.queue_wait_s,
                                         tenant=self.tenant)
        return placed

    def _emit(self, req: GenerationRequest, token: int) -> None:
        if req.first_token_t is None:
            req.first_token_t = self.clock()
            if req.ttft_s is not None:
                self.metrics.record_wait("ttft", req.ttft_s,
                                         tenant=self.tenant)
        stream = self._streams.get(req.rid)
        if stream is not None:
            stream._push(token)
        self._events.append((req.rid, token))

    def _close_stream(self, req: GenerationRequest) -> None:
        stream = self._streams.pop(req.rid, None)
        if stream is not None:
            stream._finish()

    def _release_prefix(self, req: GenerationRequest) -> None:
        keys = self._prefix_refs.pop(req.rid, None)
        if keys and self.prefix_cache is not None:
            self.prefix_cache.release(keys)

    def _finalize_unslotted(self, req, reason: str) -> None:
        """Finish a request that never occupied a slot (queued-cancel or
        deadline shed): empty output, straight to done."""
        if isinstance(req, EncodeRequest):
            req.result = None
        else:
            req.out = np.zeros(0, np.int32)
        req.finish_reason = reason
        req.finish_t = self.clock()
        self.scheduler.done.append(req)
        self._release_prefix(req)
        self._close_stream(req)

    def _finalize_slotted(self, slot: int, req, reason: str) -> None:
        """The ONE exit path for slotted requests (length/stop/cancel):
        output truncated to the request's own ``max_new_tokens``, slot
        returned to the scheduler, prefix pins released, stream closed.
        Encode requests hold a slot only within the step that admits them;
        their result (set by ``_encode_group``, None if cancelled first)
        rides on the request itself."""
        if not isinstance(req, EncodeRequest):
            req.out = np.array(self.generated[slot][:req.max_new_tokens],
                               np.int32)
        if self.paged:
            # drop every block reference the request holds (shared blocks
            # survive under their other holders / the prefix registry)
            self.kv.release_slot(slot)
        req.finish_reason = reason
        req.finish_t = self.clock()
        self.scheduler.complete(slot)
        self._release_prefix(req)
        self._close_stream(req)

    def _maybe_complete(self, slot: int, req: GenerationRequest) -> None:
        toks = self.generated[slot]
        if toks and toks[-1] in req.stop_tokens:
            self._finalize_slotted(slot, req, "stop")  # stop token stays
        elif len(toks) >= req.max_new_tokens:
            self._finalize_slotted(slot, req, "length")

    # ------------------------------------------------------------- chunked
    def _prefill_fn(self, bucket: int, n: int):
        """Batch-n full-prompt forward on an fp scratch cache, compiled once
        per (bucket, n) — n is the power-of-two padded group size."""
        fn = self._prefill_fns.get((bucket, n))
        if fn is None:
            cfg, segments, plan = self.cfg, self.segments, self.plan

            def pf(params, tokens):
                # prefill always runs on the fp cache regardless of
                # plan.kv_bits; quantization happens on slot insert
                st = plan.decode_state(n, bucket, kv_bits=16)
                logits, st2, _, _ = model_api.forward(
                    params, cfg, segments, state=st, tokens=tokens)
                return logits, st2

            fn = self._prefill_fns[(bucket, n)] = jax.jit(pf)
        return fn

    def _chunk_fn(self, scratch_len: int, n: int):
        """One prefix-block forward over the plan-precision scratch cache
        (DESIGN.md §11), compiled once per (scratch_len, n) — scratch_len is
        the bucket rounded up to the block grid, so the key space matches
        the bucket ladder. Suffix tokens attend the quantized rows of every
        EARLIER block (exactly what a prefix hit restores) and fp rows
        within their own block; the new block's rows quantize on append via
        models/transformer.write_new_kv."""
        fn = self._chunk_fns.get((scratch_len, n))
        if fn is None:
            cfg, segments = self.cfg, self.segments

            def cf(params, state, tokens):
                logits, st2, _, _ = model_api.forward(
                    params, cfg, segments, state=state, tokens=tokens)
                return logits, st2

            fn = self._chunk_fns[(scratch_len, n)] = jax.jit(
                cf, donate_argnums=(1,))
        return fn

    def _sample_first(self, logits_row, slot: int) -> int:
        return int(np.asarray(self._sample1(
            logits_row, self._seed[slot], np.int32(0), self._temp[slot],
            self._topk[slot], self._topp[slot])))

    def _emit_first_tokens(self, group, firsts) -> None:
        for (s, req), first in zip(group, firsts):
            if self.scheduler.active[s] is not req:
                continue   # an earlier emit's callback cancelled it
            self.generated[s] = [first]
            self._emit(req, first)
            if self.scheduler.active[s] is req:   # ... or a self-cancel
                self._maybe_complete(s, req)

    def _prefill_admitted(self, placed) -> None:
        """Group this round's admissions and prefill each group in one
        forward. The group key is (bucket, prefix-hit length, prefix block
        keys): same-bucket requests sharing a cached prefix (or sharing
        none) batch together; ``prefill_batch`` caps the group size."""
        jobs = []
        for s, req in placed:
            plen = len(req.prompt)
            bucket = _bucket_for(plen, self.max_len)
            m, keys = 0, ()
            if self.paged:
                self.kv.open_slot(s, req.rid)
                if self._prefix_on:
                    # registry hit: attach resident blocks BY REFERENCE —
                    # refcount++ pins them, no row copy ever happens
                    m, ids = self.pool.match(req.prompt)
                    if m:
                        self.pool.attach(req.rid, ids)
                        self.kv.extend_table(s, ids)
                    keys = tuple(ids)
                    self.metrics.record_prefix(m, plen)
            elif self.prefix_cache is not None:
                m, keys = self.prefix_cache.match(req.prompt)
                self._prefix_refs[req.rid] = keys
                self.metrics.record_prefix(m, plen)
            jobs.append((s, req, bucket, m, keys))
        groups = group_admits(jobs, key_fn=lambda j: (j[2], j[3], j[4]),
                              max_batch=self.prefill_batch)
        blocks_path = (self._prefix_on if self.paged
                       else self.prefix_cache is not None)
        for (bucket, m, keys), members in groups:
            group = [(s, req) for s, req, *_ in members
                     if self.scheduler.active[s] is req]
            if not group:      # cancelled by a callback mid-round
                continue
            if blocks_path:
                self._prefill_group_blocks(bucket, m, keys, group)
            else:
                self._prefill_group(bucket, group)

    def _prefill_group(self, bucket: int, group) -> None:
        """One batch-n fp forward covering every request in ``group``; each
        request's first token samples from its own logits row and its KV
        rows scatter (quantize-on-insert) into its own slot."""
        n = _pow2_ceil(len(group))
        toks = np.zeros((n, bucket), np.int32)
        for i, (s, req) in enumerate(group):
            toks[i, :len(req.prompt)] = req.prompt
        t0 = self.clock()
        logits, pstate = self._prefill_fn(bucket, n)(self.params,
                                                     jnp.asarray(toks))
        firsts = []
        total = 0
        fork_leaders: dict = {}
        for i, (s, req) in enumerate(group):
            plen = len(req.prompt)
            total += plen
            firsts.append(self._sample_first(logits[i, plen - 1], s))
            if self.paged:
                self._paged_insert_fp(s, req, pstate, i, fork_leaders)
            else:
                self.kv.reset_slot(s)
                self.kv.insert_prefill(s, pstate, plen, bucket, row=i)
        self.metrics.record("prefill", self.clock() - t0, total,
                            tenant=self.tenant)
        self.last_step_tokens += total
        self._emit_first_tokens(group, firsts)

    def _prefill_group_blocks(self, bucket: int, m: int, keys, group) -> None:
        """Prefix-reuse prefill (DESIGN.md §11): restore the ``m`` cached
        prefix tokens (codes + scales copy straight into the scratch cache,
        no requantization) and compute only the suffix, one prefix block per
        forward so hit and cold runs attend bit-identical rows.

        Serves both layouts: dense restores host rows from the PrefixCache
        store; paged gathers the resident pool blocks on device (same
        values — the pool's blocks hold exactly the rows a dense publish
        would have copied out)."""
        B = self.pool.block if self.paged else self.prefix_cache.block
        n = _pow2_ceil(len(group))
        t0 = self.clock()
        # scratch capacity on the BLOCK grid: a bucket capped at a
        # non-multiple-of-B max_len would make the last chunk's write run
        # past the buffer, where dynamic_update_slice clamps the start and
        # silently overwrites real rows with padding. Rounding up keeps
        # every chunk write in-bounds; the slot insert below copies only the
        # first min(S, max_len) rows back out.
        S = -(-bucket // B) * B
        state = self.plan.decode_state(n, S)
        if m:
            if self.paged:
                rows = self.pool.gather_rows(list(keys))
            else:
                rows = {key: jnp.asarray(val)
                        for key, val in self.prefix_cache.gather(keys).items()}
            state = {key: (val if key == "len" else
                           val.at[:, :, :m].set(rows[key][:, None]))
                     for key, val in state.items()}
            state["len"] = jnp.asarray(m, jnp.int32)
        max_plen = max(len(req.prompt) for _, req in group)
        n_chunks = -(-(max_plen - m) // B)
        toks = np.zeros((n, n_chunks * B), np.int32)
        for i, (s, req) in enumerate(group):
            toks[i, :len(req.prompt) - m] = req.prompt[m:]
        first_logits = [None] * len(group)
        fn = self._chunk_fn(S, n)
        for c in range(n_chunks):
            logits, state = fn(self.params, state,
                               jnp.asarray(toks[:, c * B:(c + 1) * B]))
            for i, (s, req) in enumerate(group):
                ci, pi = divmod(len(req.prompt) - 1 - m, B)
                if ci == c:    # this chunk holds the request's last token
                    first_logits[i] = logits[i, pi]
        firsts = []
        total = 0
        copy = min(S, self.max_len)     # slot rows past plen stay masked
        fork_leaders: dict = {}
        for i, (s, req) in enumerate(group):
            plen = len(req.prompt)
            total += plen - m
            firsts.append(self._sample_first(first_logits[i], s))
            if self.paged:
                self._paged_insert_state(s, req, state, i, m, fork_leaders)
                self._paged_publish(req)
            else:
                self.kv.reset_slot(s)
                self.kv.insert_rows(s, state, plen, copy, row=i)
                self._publish_prefix(req, m, state, i)
        self.metrics.record("prefill", self.clock() - t0, total,
                            tenant=self.tenant)
        self.last_step_tokens += total
        self._emit_first_tokens(group, firsts)

    def _publish_prefix(self, req: GenerationRequest, m: int, state,
                        row: int) -> None:
        """Insert the request's newly computed full blocks into the prefix
        cache (lazy device→host copy: hits never pay it)."""
        plen = len(req.prompt)
        upto = (plen // self.prefix_cache.block) * self.prefix_cache.block
        if upto <= m:
            return
        buf_keys = kv_buffer_keys(self.kv.kv_bits)
        host: dict = {}

        def rows_for_block(lo, hi):
            if not host:
                host.update({key: np.asarray(state[key][:, row])
                             for key in buf_keys})
            return {key: host[key][:, lo:hi].copy() for key in buf_keys}

        self.prefix_cache.insert(req.prompt, upto, rows_for_block)

    # --------------------------------------------------------------- paged
    def _paged_fits(self, req) -> bool:
        """Admission predicate (DESIGN.md §15): a request admits only if
        its WORST-CASE block need — every prompt + generated token, whole
        blocks — fits in free + evictable pool blocks, minus what this
        round's earlier admissions already reserved. Prefix hits only ever
        reduce the blocks actually allocated, so a reservation can never be
        exceeded. Encode requests retain no KV and always fit."""
        if isinstance(req, EncodeRequest):
            return True
        need = blocks_needed(len(req.prompt), req.max_new_tokens)
        if self.pool.available() - self._reserved < need:
            return False
        self._reserved += need
        return True

    def _fork_share(self, slot: int, req, fork_leaders: dict, lo: int,
                    nb_full: int) -> int:
        """Copy-on-write fork bookkeeping for one prefill-group member.

        The first member of a fork group in this prefill group is the
        leader (recorded); later members attach the leader's FULL prompt
        blocks ``[lo, nb_full)`` by reference and only write their own tail
        block + decode blocks — prompt KV is stored once per group, decode
        divergence stays private. (Fork members split across prefill groups
        fall back to private blocks here; with the prefix registry on they
        still converge to shared blocks via ``match`` on later arrivals.)
        Returns the first block index this member must WRITE itself."""
        if req.fork_group is None:
            return lo
        leader = fork_leaders.get(req.fork_group)
        if leader is None or leader[1] != len(req.prompt):
            fork_leaders[req.fork_group] = (slot, len(req.prompt))
            return lo
        share = self.kv.block_ids(leader[0])[lo:nb_full]
        if not share:
            return lo
        self.pool.attach(req.rid, share)
        self.kv.extend_table(slot, share)
        self.pool.cow_forks += 1
        return nb_full

    def _paged_insert_fp(self, slot: int, req, pstate, row: int,
                         fork_leaders: dict) -> None:
        """Paged analogue of ``insert_prefill``: allocate the request's
        worst-case block need up front (admission already reserved it) and
        write the prompt blocks from the fp prefill row, quantize-on-insert
        at kv_bits < 16. Decode blocks are allocated NOW, written later by
        ``append_from`` — a request can never run out of KV mid-decode."""
        B = self.pool.block
        plen = len(req.prompt)
        nb_full, nb_fill = plen // B, -(-plen // B)
        start = self._fork_share(slot, req, fork_leaders, 0, nb_full)
        own = self.pool.alloc(req.rid,
                              blocks_needed(plen, req.max_new_tokens) - start)
        self.kv.extend_table(slot, own)
        write_n = nb_fill - start
        if write_n:
            self.kv.write_fp_blocks(own[:write_n], pstate, row, start,
                                    write_n)
        self.kv.set_length(slot, plen)

    def _paged_insert_state(self, slot: int, req, state, row: int, m: int,
                            fork_leaders: dict) -> None:
        """Paged analogue of ``insert_rows`` (the prefix-chunked path):
        blocks ``[0, m/B)`` are already attached by reference, so only the
        computed-suffix blocks copy out of the plan-precision scratch —
        same precision, no requantization."""
        B = self.pool.block
        plen = len(req.prompt)
        nb_full, nb_fill = plen // B, -(-plen // B)
        start = self._fork_share(slot, req, fork_leaders, m // B, nb_full)
        own = self.pool.alloc(req.rid,
                              blocks_needed(plen, req.max_new_tokens) - start)
        self.kv.extend_table(slot, own)
        write_n = nb_fill - start
        if write_n:
            self.kv.write_state_blocks(own[:write_n], state, row, start * B,
                                       write_n)
        self.kv.set_length(slot, plen)

    def _paged_publish(self, req) -> None:
        """Register the request's full prompt blocks in the pool's prefix
        registry (pure bookkeeping — the blocks ARE the cache; no device→
        host copy, the dense path's lazy-copy publish disappears)."""
        if not self._prefix_on:
            return
        plen = len(req.prompt)
        upto = (plen // self.pool.block) * self.pool.block
        if upto:
            self.pool.publish(req.rid, req.prompt, upto)

    # -------------------------------------------------------------- encode
    def _encode_fn(self, bucket: int, n: int):
        """Batch-n prefill-only forward, compiled once per (bucket, n) —
        the same compile-key space as ``_prefill_fn``. Encoder plans run the
        bidirectional stack with per-row length masking (bucket padding
        stays bit-exact, see serving/encoder.py) and return every head the
        artifact carries; decode plans return the prompt log-likelihood
        (causal attention, so padded tails are free) as ``score``."""
        fn = self._encode_fns.get((bucket, n))
        if fn is None:
            cfg, segments, plan = self.cfg, self.segments, self.plan
            if self.mode == "encoder":
                has_cls = "classifier" in self.params

                def ef(params, tokens, lengths):
                    h, _ = bert_encode(params, cfg, segments, tokens,
                                       lengths=lengths)
                    out = {"embed": bert_pool(params, h)}
                    if has_cls:
                        logits = (out["embed"] @ params["classifier"]["w"]
                                  + params["classifier"]["b"])
                        logp = jax.nn.log_softmax(
                            logits.astype(jnp.float32), axis=-1)
                        out["classify"] = logits
                        # relevance score: positive-class log-probability
                        out["score"] = (logp[:, 1] if logits.shape[-1] >= 2
                                        else logp[:, 0])
                    return out
            else:
                def ef(params, tokens, lengths):
                    st = plan.decode_state(n, bucket, kv_bits=16)
                    logits, _, _, _ = model_api.forward(
                        params, cfg, segments, state=st, tokens=tokens)
                    logp = jax.nn.log_softmax(
                        logits.astype(jnp.float32), axis=-1)
                    ll = jnp.take_along_axis(
                        logp[:, :-1], tokens[:, 1:, None], -1)[..., 0]
                    mask = (jnp.arange(bucket - 1)[None, :] + 1
                            < lengths[:, None])
                    return {"score": jnp.sum(jnp.where(mask, ll, 0.0),
                                             axis=1)}

            fn = self._encode_fns[(bucket, n)] = jax.jit(ef)
        return fn

    def _encode_admitted(self, placed) -> None:
        """Group this round's encode admissions by bucket and run each
        group as one forward (``prefill_batch`` caps the group size, n pads
        to a power of two — the PR-5 grouping, reused verbatim)."""
        jobs = [(s, req, _bucket_for(len(req.tokens), self.max_len))
                for s, req in placed]
        groups = group_admits(jobs, key_fn=lambda j: j[2],
                              max_batch=self.prefill_batch)
        for bucket, members in groups:
            group = [(s, req) for s, req, _ in members
                     if self.scheduler.active[s] is req]
            if not group:      # cancelled by a callback mid-round
                continue
            self._encode_group(bucket, group)

    def _encode_group(self, bucket: int, group) -> None:
        """One batched forward; every request resolves (and frees its slot)
        before this returns — encode requests never outlive their step."""
        n = _pow2_ceil(len(group))
        toks = np.zeros((n, bucket), np.int32)
        lens = np.ones(n, np.int32)      # padding rows: length-1, masked
        total = 0
        for i, (s, req) in enumerate(group):
            plen = len(req.tokens)
            toks[i, :plen] = req.tokens
            lens[i] = plen
            total += plen
        t0 = self.clock()
        out = self._encode_fn(bucket, n)(self.params, jnp.asarray(toks),
                                         jnp.asarray(lens))
        out = {task: np.asarray(v) for task, v in out.items()}
        self.metrics.record("encode", self.clock() - t0, total,
                            tenant=self.tenant)
        self.last_step_encode_tokens += total
        self.last_step_tokens += total
        for i, (s, req) in enumerate(group):
            if self.scheduler.active[s] is not req:
                continue   # an earlier on_result callback cancelled it
            req.result = out[req.task][i]
            self._finalize_slotted(s, req, "done")
            if req.latency_s is not None:
                self.metrics.record_wait("encode_latency", req.latency_s,
                                         tenant=self.tenant)

    def _encoder_step(self) -> None:
        """mode='encoder': the whole step is admit + batched encode — there
        is no decode phase and no KV to carry forward."""
        placed = self._admit()
        if placed:
            self._encode_admitted(placed)

    def _gen_steps(self) -> np.ndarray:
        """Per-slot index of the NEXT generated token (the sampling step fed
        to ``fold_in``), so token i of a request always draws from the same
        key regardless of batch composition."""
        return np.array([len(self.generated[s]) for s in range(self.slots)],
                        np.int32)

    def _chunked_step(self) -> None:
        fits = None
        if self.paged:
            # ONE byte budget drives admission: reservations are per-round
            # (prefill below turns them into real allocations)
            self._reserved = 0
            fits = self._paged_fits
        placed = self._admit(fits=fits)
        if placed:
            # encode and generation traffic arrive through one admit round:
            # encode jobs resolve immediately (freeing their slots), then
            # the generation jobs prefill and join the decode batch below.
            enc = [(s, r) for s, r in placed if isinstance(r, EncodeRequest)]
            gen = [(s, r) for s, r in placed
                   if not isinstance(r, EncodeRequest)]
            if enc:
                self._encode_admitted(enc)
            if gen:
                self._prefill_admitted(gen)
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.generated[s][-1]
        t0 = self.clock()
        if self.paged:
            # block-table indirection for the jnp reference path: gather a
            # dense-shaped view and feed the SAME jitted step the dense
            # layout compiled — garbage rows from table padding are masked
            # to exact zeros inside the attention (DESIGN.md §15), so the
            # streams stay bit-identical. The step writes each slot's new
            # row into the (donated) view; append_from scatters it back to
            # the pool block its table maps that position to.
            state = self.kv.gather_state()
            next_tok, new_state = self._step(
                self.params, state, jnp.asarray(toks),
                self._seed, self._gen_steps(), self._temp, self._topk,
                self._topp)
            self.kv.append_from(new_state, active)
        else:
            next_tok, self.kv.state = self._step(
                self.params, self.kv.state, jnp.asarray(toks),
                self._seed, self._gen_steps(), self._temp, self._topk,
                self._topp)
        next_tok = np.asarray(next_tok)
        self.metrics.record("decode", self.clock() - t0, len(active),
                            tenant=self.tenant)
        self.last_step_tokens += len(active)
        for s in active:
            req = self.scheduler.active[s]
            if req is None:    # freed mid-step by an on_token cancel()
                continue
            self.generated[s].append(int(next_tok[s]))
            self._emit(req, int(next_tok[s]))
            if self.scheduler.active[s] is req:   # ... or a self-cancel
                self._maybe_complete(s, req)

    # --------------------------------------------------------------- token
    def _token_fits(self, req: GenerationRequest) -> bool:
        """Token mode shares ONE cache cursor across slots: a request
        admitted at cursor c consumes positions [c, c + plen + max_new), so
        it fits iff that span ends inside max_len."""
        return (self._cursor + len(req.prompt) + req.max_new_tokens
                <= self.max_len)

    def _token_step(self) -> None:
        """Seed semantics: prompts fed one token per batched step (global
        cache cursor; used by families without a KV slot cache). The shared
        cursor only advances — so admission is gated on the LIVE cursor
        (submit's per-request check is necessary, not sufficient), and an
        idle engine resets its decode state instead of admitting work whose
        KV writes would silently clamp past max_len."""
        fits = None
        if self.cfg.family != "xlstm":   # recurrent state: nothing to exhaust
            fits = self._token_fits
            head = self.scheduler.peek()
            if (head is not None and self.scheduler.num_active == 0
                    and self._cursor > 0 and not fits(head)):
                # drained but the cursor is spent: fresh state, cursor 0.
                # submit() guarantees every queued request fits from there.
                self.state = self._place_state(
                    self.plan.decode_state(self.slots, self.max_len))
                self._cursor = 0
        for s, _req in self._admit(fits=fits):
            self.pos[s] = 0
        active = self.scheduler.active_slots()
        if not active:
            return
        if self.cfg.family != "xlstm" and self._cursor >= self.max_len:
            raise RuntimeError(
                f"token-mode cache cursor exhausted mid-flight (cursor "
                f"{self._cursor} >= max_len {self.max_len}) with "
                f"{len(active)} active request(s) — admission gating "
                "should have prevented this")
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.scheduler.active[s]
            if self.pos[s] < len(req.prompt):      # still feeding the prompt
                toks[s, 0] = req.prompt[self.pos[s]]
            else:                                  # submit() bans empty
                toks[s, 0] = self.generated[s][-1]  # prompts: always filled
        t0 = self.clock()
        next_tok, self.state = self._step(
            self.params, self.state, jnp.asarray(toks),
            self._seed, self._gen_steps(), self._temp, self._topk,
            self._topp)
        next_tok = np.asarray(next_tok)
        self._cursor += 1
        # a slot emits a generated token this step once it has consumed its
        # last prompt token, i.e. pos >= plen - 1 before the increment
        n_decoding = sum(
            self.pos[s] >= len(self.scheduler.active[s].prompt) - 1
            for s in active)
        self.metrics.record("decode", self.clock() - t0, n_decoding,
                            tenant=self.tenant)
        self.last_step_tokens += len(active)
        for s in active:
            req = self.scheduler.active[s]
            if req is None:    # freed mid-step by an on_token cancel()
                continue
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                self.generated[s].append(int(next_tok[s]))
                self._emit(req, int(next_tok[s]))
                if self.scheduler.active[s] is req:   # ... or a self-cancel
                    self._maybe_complete(s, req)
