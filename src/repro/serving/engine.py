"""Serving engine: prefill/decode-separated step loop (DESIGN.md §7).

Two-phase execution over the deployed int4/int8 model:

* **prefill** — a newly admitted request's whole prompt runs in ONE forward
  (batch 1, prompt padded to a power-of-two bucket to bound recompiles); the
  resulting per-layer KV rows are scattered into the request's slot and the
  first output token falls out of the same pass.
* **decode** — one token per step for every occupied slot, batched across the
  slot table with per-slot cache cursors (kv_cache.SlotKVCache).

This replaces the seed driver's token-at-a-time prompt feeding (prompt_len
engine steps per request, each a full batched forward) with prompt_len tokens
per prefill step — and isolates slots, which the seed's global cache cursor
did not.

Families without a {'k','v','len'} decode cache (xlstm, hybrid, encdec) fall
back to ``prefill_mode='token'``: the seed semantics with a shared cursor.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import api
from .kv_cache import SlotKVCache
from .metrics import ServeMetrics
from .scheduler import Request, Scheduler

_TOKEN_ONLY_FAMILIES = ("xlstm", "hybrid", "encdec")


def _bucket_for(plen: int, max_len: int, min_bucket: int = 8) -> int:
    b = min_bucket
    while b < plen:
        b *= 2
    return min(b, max_len)


class ServingEngine:
    """Continuous-batching engine over the deployed quantized model."""

    def __init__(self, params_int, cfg: ModelConfig, segments, *,
                 slots: int = 8, max_len: int = 512, dtype=jnp.float32,
                 prefill_mode: str = "auto", kv_bits: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None):
        self.cfg = cfg
        self.segments = segments
        self.params = params_int
        self.slots = slots
        self.max_len = max_len
        self.dtype = dtype
        self.kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
        self.scheduler = Scheduler(slots)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.generated: list[list[int]] = [[] for _ in range(slots)]

        if prefill_mode == "auto":
            prefill_mode = ("token" if cfg.family in _TOKEN_ONLY_FAMILIES
                            else "chunked")
        if prefill_mode == "chunked" and cfg.family in _TOKEN_ONLY_FAMILIES:
            raise ValueError(
                f"{cfg.family}: no KV slot cache; use prefill_mode='token'")
        if prefill_mode == "token" and self.kv_bits != 16:
            raise ValueError(
                "kv_bits < 16 needs the chunked slot cache; token-mode "
                "families keep the fp decode state")
        self.prefill_mode = prefill_mode

        if prefill_mode == "chunked":
            self.kv = SlotKVCache(cfg, slots, max_len, dtype=dtype,
                                  kv_bits=self.kv_bits)
            self.state = None
            self._prefill_fns: dict[int, callable] = {}
        else:
            self.kv = None
            self.state = api.decode_state(cfg, slots, max_len, dtype=dtype)
            self.pos = np.zeros(slots, np.int32)   # per-slot prompt cursor

        def step(params, state, tokens):
            logits, new_state, _, _ = api.forward(
                params, cfg, segments, state=state, tokens=tokens)
            return jnp.argmax(logits[:, -1], axis=-1), new_state

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> Request:
        return self.scheduler.submit(req)

    @property
    def done(self) -> list[Request]:
        return self.scheduler.done

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def active(self):
        return self.scheduler.active

    def run_until_drained(self, max_steps: int = 10000) -> int:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.engine_step()
            steps += 1
        return steps

    def engine_step(self) -> None:
        if self.prefill_mode == "chunked":
            self._chunked_step()
        else:
            self._token_step()

    # ------------------------------------------------------------- chunked
    def _prefill_fn(self, bucket: int):
        """Batch-1 full-prompt forward, compiled once per bucket size."""
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            cfg, segments, dtype = self.cfg, self.segments, self.dtype

            def pf(params, tokens):
                # prefill always runs on the fp cache regardless of
                # cfg.kv_bits; quantization happens on slot insert
                st = api.decode_state(cfg, 1, bucket, dtype=dtype,
                                      kv_bits=16)
                logits, st2, _, _ = api.forward(
                    params, cfg, segments, state=st, tokens=tokens)
                return logits, st2

            fn = self._prefill_fns[bucket] = jax.jit(pf)
        return fn

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        plen = len(req.prompt)
        if plen <= 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if plen + req.max_new_tokens > self.max_len:
            # past max_len the cache scatter drops writes silently — decode
            # would keep emitting tokens that cannot see recent context
            raise ValueError(
                f"request {req.rid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_len "
                f"({self.max_len})")
        bucket = _bucket_for(plen, self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        t0 = time.perf_counter()
        logits, pstate = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks))
        first = int(np.asarray(jnp.argmax(logits[0, plen - 1])))
        self.kv.reset_slot(slot)
        self.kv.insert_prefill(slot, pstate, plen, bucket)
        self.metrics.record("prefill", time.perf_counter() - t0, plen)
        self.generated[slot] = [first]
        self._maybe_complete(slot, req)

    def _chunked_step(self) -> None:
        for s, req in self.scheduler.admit():
            self._prefill_into_slot(s, req)
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.generated[s][-1]
        t0 = time.perf_counter()
        next_tok, self.kv.state = self._step(self.params, self.kv.state,
                                             jnp.asarray(toks))
        next_tok = np.asarray(next_tok)
        self.metrics.record("decode", time.perf_counter() - t0, len(active))
        for s in active:
            req = self.scheduler.active[s]
            self.generated[s].append(int(next_tok[s]))
            self._maybe_complete(s, req)

    def _maybe_complete(self, slot: int, req: Request) -> None:
        if len(self.generated[slot]) >= req.max_new_tokens:
            req.out = np.array(self.generated[slot][:req.max_new_tokens],
                               np.int32)
            self.scheduler.complete(slot)

    # --------------------------------------------------------------- token
    def _token_step(self) -> None:
        """Seed semantics: prompts fed one token per batched step (global
        cache cursor; used by families without a KV slot cache)."""
        for s, _req in self.scheduler.admit():
            self.generated[s] = []
            self.pos[s] = 0
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.scheduler.active[s]
            if self.pos[s] < len(req.prompt):      # still feeding the prompt
                toks[s, 0] = req.prompt[self.pos[s]]
            elif self.generated[s]:
                toks[s, 0] = self.generated[s][-1]
            else:
                toks[s, 0] = req.prompt[-1]
        t0 = time.perf_counter()
        next_tok, self.state = self._step(self.params, self.state,
                                          jnp.asarray(toks))
        next_tok = np.asarray(next_tok)
        # a slot emits a generated token this step once it has consumed its
        # last prompt token, i.e. pos >= plen - 1 before the increment
        n_decoding = sum(
            self.pos[s] >= len(self.scheduler.active[s].prompt) - 1
            for s in active)
        self.metrics.record("decode", time.perf_counter() - t0, n_decoding)
        for s in active:
            req = self.scheduler.active[s]
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                self.generated[s].append(int(next_tok[s]))
                self._maybe_complete(s, req)
