"""Serving engine: prefill/decode-separated step loop (DESIGN.md §7).

Two-phase execution over a deployed model (``repro.deploy.DeployedModel``, or
a raw params tree plus its ``ExecutionPlan``):

* **prefill** — a newly admitted request's whole prompt runs in ONE forward
  (batch 1, prompt padded to a power-of-two bucket to bound recompiles); the
  resulting per-layer KV rows are scattered into the request's slot and the
  first output token falls out of the same pass.
* **decode** — one token per step for every occupied slot, batched across the
  slot table with per-slot cache cursors (kv_cache.SlotKVCache).

Everything configuration-shaped — segments, kernel selection, KV precision,
prefill mode, decode dtype — comes from the plan; the engine itself only owns
slots, max_len and the step loop. Family compatibility was validated when the
plan was built, so construction here cannot produce an inconsistent engine.

Families without a {'k','v','len'} decode cache (xlstm, hybrid, encdec) run
``prefill_mode='token'``: the seed semantics with a shared cursor.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..deploy import DeployedModel, ExecutionPlan
from ..models import api
from .kv_cache import SlotKVCache
from .metrics import ServeMetrics
from .scheduler import Request, Scheduler


def _bucket_for(plen: int, max_len: int, min_bucket: int = 8) -> int:
    b = min_bucket
    while b < plen:
        b *= 2
    return min(b, max_len)


class ServingEngine:
    """Continuous-batching engine over the deployed quantized model.

    ``model`` is a :class:`DeployedModel` (plan included), or a raw params
    tree with ``plan`` passed explicitly.
    """

    def __init__(self, model, plan: Optional[ExecutionPlan] = None, *,
                 slots: int = 8, max_len: int = 512,
                 metrics: Optional[ServeMetrics] = None):
        if isinstance(model, DeployedModel):
            if plan is not None and plan != model.plan:
                raise ValueError(
                    "pass either a DeployedModel (plan included) or raw "
                    "params + plan, not a conflicting pair")
            params, plan = model.params, model.plan
        else:
            params = model
            if plan is None:
                raise TypeError("raw params need an ExecutionPlan; build one "
                                "with repro.deploy.ExecutionPlan.build")
        self.plan = plan
        self.cfg = cfg = plan.cfg
        self.segments = segments = plan.segments
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.dtype = plan.jnp_dtype           # the ONE serving decode dtype
        self.kv_bits = plan.kv_bits
        self.prefill_mode = plan.prefill_mode
        self.scheduler = Scheduler(slots)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.generated: list[list[int]] = [[] for _ in range(slots)]

        if self.prefill_mode == "chunked":
            self.kv = SlotKVCache.from_plan(plan, slots, max_len)
            self.state = None
            self._prefill_fns: dict[int, callable] = {}
        else:
            self.kv = None
            self.state = plan.decode_state(slots, max_len)
            self.pos = np.zeros(slots, np.int32)   # per-slot prompt cursor

        def step(params, state, tokens):
            logits, new_state, _, _ = api.forward(
                params, cfg, segments, state=state, tokens=tokens)
            return jnp.argmax(logits[:, -1], axis=-1), new_state

        self._step = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> Request:
        """Validate + enqueue. Malformed requests are rejected HERE, for
        both prefill modes — by decode time the bad prompt would have been
        scattered into the cache (or indexed at [-1]) already."""
        self.scheduler.assign_id(req)      # so rejections carry a real rid
        plen = len(req.prompt)
        if plen <= 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if plen + req.max_new_tokens > self.max_len and \
                self.cfg.family != "xlstm":
            # past max_len the cache writes clamp or drop silently — decode
            # would keep emitting tokens that cannot see recent context.
            # (xlstm state is recurrent: no positional cache to overflow.
            # Token mode's shared cursor makes this necessary, not
            # sufficient — inherited seed semantics.)
            raise ValueError(
                f"request {req.rid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_len "
                f"({self.max_len})")
        return self.scheduler.submit(req)

    @property
    def done(self) -> list[Request]:
        return self.scheduler.done

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def active(self):
        return self.scheduler.active

    def run_until_drained(self, max_steps: int = 10000) -> int:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.engine_step()
            steps += 1
        return steps

    def engine_step(self) -> None:
        if self.prefill_mode == "chunked":
            self._chunked_step()
        else:
            self._token_step()

    # ------------------------------------------------------------- chunked
    def _prefill_fn(self, bucket: int):
        """Batch-1 full-prompt forward, compiled once per bucket size."""
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            cfg, segments, plan = self.cfg, self.segments, self.plan

            def pf(params, tokens):
                # prefill always runs on the fp cache regardless of
                # plan.kv_bits; quantization happens on slot insert
                st = plan.decode_state(1, bucket, kv_bits=16)
                logits, st2, _, _ = api.forward(
                    params, cfg, segments, state=st, tokens=tokens)
                return logits, st2

            fn = self._prefill_fns[bucket] = jax.jit(pf)
        return fn

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        plen = len(req.prompt)
        assert plen > 0, f"request {req.rid}: empty prompt past submit()"
        bucket = _bucket_for(plen, self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        t0 = time.perf_counter()
        logits, pstate = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks))
        first = int(np.asarray(jnp.argmax(logits[0, plen - 1])))
        self.kv.reset_slot(slot)
        self.kv.insert_prefill(slot, pstate, plen, bucket)
        self.metrics.record("prefill", time.perf_counter() - t0, plen)
        self.generated[slot] = [first]
        self._maybe_complete(slot, req)

    def _chunked_step(self) -> None:
        for s, req in self.scheduler.admit():
            self._prefill_into_slot(s, req)
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.generated[s][-1]
        t0 = time.perf_counter()
        next_tok, self.kv.state = self._step(self.params, self.kv.state,
                                             jnp.asarray(toks))
        next_tok = np.asarray(next_tok)
        self.metrics.record("decode", time.perf_counter() - t0, len(active))
        for s in active:
            req = self.scheduler.active[s]
            self.generated[s].append(int(next_tok[s]))
            self._maybe_complete(s, req)

    def _maybe_complete(self, slot: int, req: Request) -> None:
        if len(self.generated[slot]) >= req.max_new_tokens:
            req.out = np.array(self.generated[slot][:req.max_new_tokens],
                               np.int32)
            self.scheduler.complete(slot)

    # --------------------------------------------------------------- token
    def _token_step(self) -> None:
        """Seed semantics: prompts fed one token per batched step (global
        cache cursor; used by families without a KV slot cache)."""
        for s, _req in self.scheduler.admit():
            self.generated[s] = []
            self.pos[s] = 0
        active = self.scheduler.active_slots()
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.scheduler.active[s]
            if self.pos[s] < len(req.prompt):      # still feeding the prompt
                toks[s, 0] = req.prompt[self.pos[s]]
            else:                                  # submit() bans empty
                toks[s, 0] = self.generated[s][-1]  # prompts: always filled
        t0 = time.perf_counter()
        next_tok, self.state = self._step(self.params, self.state,
                                          jnp.asarray(toks))
        next_tok = np.asarray(next_tok)
        # a slot emits a generated token this step once it has consumed its
        # last prompt token, i.e. pos >= plen - 1 before the increment
        n_decoding = sum(
            self.pos[s] >= len(self.scheduler.active[s].prompt) - 1
            for s in active)
        self.metrics.record("decode", time.perf_counter() - t0, n_decoding)
        for s in active:
            req = self.scheduler.active[s]
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                self.generated[s].append(int(next_tok[s]))
                self._maybe_complete(s, req)
