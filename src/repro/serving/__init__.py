"""int4 serving subsystem (DESIGN.md §7, generation API §10).

The deployment side of the paper, grown into a real package:

* ``api``        — the generation surface: ``GenerationRequest`` /
  ``SamplingParams`` (temperature/top-k/top-p/seed, stop tokens, priority,
  deadline), ``TokenStream`` handles that yield tokens as they are produced
  (iterator + callback forms), ``GenerationResult``, and the batched
  sampling math (greedy == temperature 0)
* ``scheduler``  — priority queue (bounded, deadline-shedding) + fixed slot
  table, continuous-batching refill
* ``kv_cache``   — slot-state manager (per-layer KV cache, per-slot lengths,
  optional int8/int4 quantization with per-(token, head) scales — DESIGN.md §8)
* ``prefix_cache`` — refcounted, LRU-evicted, byte-budgeted store of
  quantized KV prefix blocks for shared-prefix reuse (DESIGN.md §11)
* ``block_pool``  — paged KV memory subsystem (DESIGN.md §15):
  ``BlockPool`` (refcounted block-table allocator over quantized KV blocks,
  one byte budget for admission AND LRU eviction, prefix registry shared by
  reference, copy-on-write forks) + ``PagedKVCache`` (the engine-facing
  slot view); ``plan.kv_paging='paged'`` switches the engine onto it with
  bit-identical token streams
* ``engine``     — prefill/decode-separated step loop over the deployed
  model (batched bucketed prefill, prefix reuse); ``engine_step()`` is the
  public pump, ``cancel(rid)`` frees a slot and its KV state mid-flight
* ``encoder``    — prefill-only request surface (DESIGN.md §14):
  ``EncodeRequest`` (classify / embed / score) resolves in the step that
  admits it — one batched bucketed forward, no KV retention — through the
  same scheduler/deadline/cancel machinery as generation traffic
* ``tenants``    — ``MultiTenantEngine``: several deployed artifacts in one
  process behind one pump, per-tenant bounded queues + token-budget quotas
  (``QuotaExceededError``) and deficit-round-robin fair-share admission
* ``replicas``   — ``ReplicaSet`` (DESIGN.md §16): N engines over ONE
  deployed model behind one admission queue — least-loaded dispatch, one
  shared rid space, every replica pumped per ``engine_step()`` (concurrent
  data-parallel capacity, composing with the plan's tensor-parallel ``tp``
  axis)
* ``metrics``    — latency/throughput recorder (tokens/sec, p50/p99 steps,
  TTFT and queue-wait percentiles, prefix hit rate; bounded windows +
  ``pop_summary()`` drain)
* ``clock``      — the injectable time source every serving component reads
  (DESIGN.md §12): ``SYSTEM_CLOCK`` (``time.monotonic``) by default, or a
  deterministic ``VirtualClock`` for simulation tests
* ``loadgen``    — trace-driven closed-loop load generator (Poisson /
  recorded-trace arrivals, shared-prefix mix, priorities, deadlines,
  cancellations) reporting SLO goodput with bootstrap confidence
  intervals, in wall-clock or virtual-clock mode (DESIGN.md §12)

``launch/serve.py`` is a thin CLI shim over this package. The engine
consumes a ``repro.deploy`` DeployedModel (or raw params + ExecutionPlan) —
segments, kernel selection, KV precision, prefill mode, decode dtype and
default sampling all come from the plan (DESIGN.md §9).

``Request`` (the seed-era dataclass) remains importable as a deprecation
shim over ``GenerationRequest``.
"""
from .api import (FINISH_REASONS, GenerationRequest, GenerationResult,
                  QueueFullError, Request, SamplingParams, TokenStream,
                  sample_seed)
from .block_pool import BlockPool, PagedKVCache, blocks_needed
from .clock import SYSTEM_CLOCK, Clock, VirtualClock
from .encoder import (ENCODE_TASKS, EncodeHandle, EncodeRequest,
                      EncodeResult)
from .engine import ServingEngine
from .kv_cache import SlotKVCache
from .loadgen import (SLO, Arrival, LoadResult, VirtualCost, Workload,
                      bootstrap_summary, make_arrivals, run_load, run_trials,
                      trace_arrivals)
from .metrics import ServeMetrics
from .prefix_cache import PrefixCache
from .replicas import ReplicaSet
from .scheduler import Scheduler
from .tenants import MultiTenantEngine, QuotaExceededError, TenantState

__all__ = ["Arrival", "BlockPool", "Clock", "ENCODE_TASKS", "EncodeHandle",
           "EncodeRequest", "EncodeResult", "FINISH_REASONS",
           "GenerationRequest", "GenerationResult", "LoadResult",
           "MultiTenantEngine", "PagedKVCache", "PrefixCache",
           "QueueFullError", "QuotaExceededError", "ReplicaSet", "Request",
           "SLO",
           "SYSTEM_CLOCK", "SamplingParams", "Scheduler", "ServeMetrics",
           "ServingEngine", "SlotKVCache", "TenantState", "TokenStream",
           "VirtualClock", "VirtualCost", "Workload", "blocks_needed",
           "bootstrap_summary", "make_arrivals", "run_load", "run_trials",
           "sample_seed", "trace_arrivals"]
