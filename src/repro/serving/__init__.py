"""int4 serving subsystem (DESIGN.md §7).

The deployment side of the paper, grown into a real package:

* ``scheduler``  — request queue + fixed slot table, continuous-batching refill
* ``kv_cache``   — slot-state manager (per-layer KV cache, per-slot lengths,
  optional int8/int4 quantization with per-(token, head) scales — DESIGN.md §8)
* ``engine``     — prefill/decode-separated step loop over the deployed model
* ``metrics``    — latency/throughput recorder (tokens/sec, p50/p99 steps)

``launch/serve.py`` is a thin CLI shim over this package. The engine
consumes a ``repro.deploy`` DeployedModel (or raw params + ExecutionPlan) —
segments, kernel selection, KV precision, prefill mode and decode dtype all
come from the plan (DESIGN.md §9).
"""
from .engine import ServingEngine
from .kv_cache import SlotKVCache
from .metrics import ServeMetrics
from .scheduler import Request, Scheduler

__all__ = ["Request", "Scheduler", "ServingEngine", "SlotKVCache",
           "ServeMetrics"]
