"""Shared-prefix KV reuse for the serving engine (DESIGN.md §11).

Under repeated-prefix traffic (system prompts, few-shot templates) every
admission used to recompute the same leading prompt tokens from scratch.
This module caches the QUANTIZED KV rows those tokens produce — codes plus
per-(token, head) scales, the DESIGN.md §8 layout — so a later request that
shares the prefix scatters the cached rows straight into its slot and only
prefills the suffix. Per-(token, head) scales make the rows slot-portable by
construction: no other row's scale is involved, so no requantization happens
on either side of the copy.

Structure:

* **block granularity** — prefixes are cached in fixed ``block``-token units
  (``PREFIX_BLOCK``, aligned with the engine's minimum prefill bucket). A
  block entry covers prompt tokens ``[j*B, (j+1)*B)`` and is keyed by a
  rolling hash of the FULL prefix ``prompt[:(j+1)*B]`` — a chained blake2b
  digest (``key_j = H(key_{j-1} || block_tokens)``), so extending a prefix
  by one block is O(block) and a key commits to EVERY token before it, not
  just the newest block. Lookups walk the chain block by block and stop at
  the first miss. Collisions would require breaking the digest; as belt and
  braces every entry also stores its block's tokens and a match requires
  them to compare equal — a mismatch degrades to a miss, never to wrong KV.
* **refcounts** — ``match()`` pins the blocks it returns; the engine releases
  them when the request finishes (complete / stop / cancel). Pinned blocks
  are never evicted, so a hot prefix cannot be evicted out from under an
  in-flight admission (the budget may transiently overshoot instead).
* **LRU + byte budget** — entries account their exact host bytes
  (``kernels/kv_pack.kv_row_bytes`` is the per-row arithmetic); once the
  budget is exceeded, unpinned entries evict oldest-use first. int4 KV
  compounds here: ~7x smaller rows than f32 mean ~7x more cacheable prefix
  tokens per byte.

The cache stores host (numpy) copies — it lives across engine steps and must
not pin device buffers. Byte-identity of hit-vs-cold streams is the engine's
contract (DESIGN.md §11): prefill quantizes block-by-block, so the rows a
cold run attends to are bit-equal to the rows a hit copies out of the cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

__all__ = ["PrefixCache", "PREFIX_BLOCK", "rolling_hash", "HASH_SEED"]

#: prefix granularity in tokens; equals the engine's minimum prefill bucket
#: so block boundaries always align with bucket boundaries.
PREFIX_BLOCK = 8

#: initial value of the chained prefix digest (the empty prefix)
HASH_SEED = b""


def rolling_hash(h: bytes, tokens) -> bytes:
    """Extend prefix digest ``h`` by one block of ``tokens``.

    ``key_j = blake2b(key_{j-1} || tokens_le32)``: incremental like a
    polynomial rolling hash, but each key commits to the ENTIRE prefix — a
    weaker hash verified only against the final block's tokens would let a
    constructible full-prefix collision serve another prompt's KV."""
    return hashlib.blake2b(
        h + np.asarray(tokens, np.int32).tobytes(),
        digest_size=16).digest()


@dataclasses.dataclass
class _Entry:
    key: bytes               # chained digest of the whole prefix ending here
    tokens: np.ndarray       # this block's tokens (defense-in-depth check)
    rows: dict               # buffer key -> (L, block, ...) host array
    nbytes: int
    refs: int = 0


class PrefixCache:
    """Refcounted, LRU-evicted, byte-budgeted store of quantized KV blocks."""

    def __init__(self, budget_bytes: int, block: int = PREFIX_BLOCK):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        self.budget = int(budget_bytes)
        self.block = int(block)
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self.bytes = 0
        # counters (host ints, never grow): per-request hit/miss plus token
        # totals; the engine mirrors these into ServeMetrics.
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.tokens_reused = 0

    # ---------------------------------------------------------------- lookup
    def match(self, prompt) -> tuple[int, tuple[bytes, ...]]:
        """Longest cached block-aligned prefix of ``prompt``, capped at
        ``len(prompt) - 1`` tokens — the last prompt token must always be
        computed to produce the first output logits.

        Returns ``(m, keys)``: ``m`` reusable tokens and the pinned block
        keys (refcount incremented; pass to :meth:`release` when the request
        finishes, hit or not)."""
        B = self.block
        h = HASH_SEED
        keys: list[bytes] = []
        m = 0
        j = 0
        while (j + 1) * B <= len(prompt) - 1:
            blk = np.asarray(prompt[j * B:(j + 1) * B], np.int32)
            h = rolling_hash(h, blk)
            entry = self._entries.get(h)
            if entry is None or not np.array_equal(entry.tokens, blk):
                break                      # first miss (or hash collision)
            self._entries.move_to_end(h)   # LRU touch
            entry.refs += 1
            keys.append(h)
            m = (j + 1) * B
            j += 1
        if m:
            self.hits += 1
        else:
            self.misses += 1
        self.tokens_reused += m
        return m, tuple(keys)

    def gather(self, keys) -> dict:
        """Concatenate pinned block rows into one ``(L, m, ...)`` array per
        buffer key, in prefix order."""
        entries = [self._entries[k] for k in keys]
        return {bk: np.concatenate([e.rows[bk] for e in entries], axis=1)
                for bk in entries[0].rows}

    def release(self, keys) -> None:
        """Unpin blocks acquired by :meth:`match`; runs deferred eviction."""
        for k in keys:
            entry = self._entries.get(k)
            if entry is not None and entry.refs > 0:
                entry.refs -= 1
        self._evict()

    # ---------------------------------------------------------------- insert
    def insert(self, prompt, upto: int, rows_for_block) -> int:
        """Publish the blocks covering ``prompt[:upto]`` that are not cached
        yet. ``rows_for_block(lo, hi)`` must return the host-array dict for
        token rows ``[lo, hi)`` — it is only called for missing blocks, so
        hits never pay the device→host copy. Returns blocks inserted."""
        B = self.block
        h = HASH_SEED
        added = 0
        for j in range(upto // B):
            blk = np.asarray(prompt[j * B:(j + 1) * B], np.int32)
            h = rolling_hash(h, blk)
            entry = self._entries.get(h)
            if entry is not None:
                if np.array_equal(entry.tokens, blk):
                    self._entries.move_to_end(h)
                    continue
                if entry.refs > 0:
                    # hash collision with a pinned entry: leave it alone; the
                    # chain for THIS prompt simply stops being cacheable here
                    break
                self.bytes -= entry.nbytes     # unpinned collision: replace
                del self._entries[h]
            rows = {bk: np.asarray(a) for bk, a in
                    rows_for_block(j * B, (j + 1) * B).items()}
            nbytes = sum(a.nbytes for a in rows.values()) + blk.nbytes
            self._entries[h] = _Entry(h, blk, rows, nbytes)
            self.bytes += nbytes
            added += 1
        self._evict()
        return added

    # --------------------------------------------------------------- queries
    def _evict(self) -> None:
        while self.bytes > self.budget:
            victim = next((k for k, e in self._entries.items()
                           if e.refs == 0), None)
            if victim is None:       # everything pinned: transient overshoot
                break
            self.bytes -= self._entries.pop(victim).nbytes
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "blocks": len(self._entries),
            "bytes": self.bytes,
            "budget_bytes": self.budget,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "tokens_reused": self.tokens_reused,
            "evictions": self.evictions,
        }
