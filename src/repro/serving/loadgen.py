"""Trace-driven closed-loop load generator + SLO-goodput reporting
(DESIGN.md §12).

The quick fixed-burst bench measured tok/s over a handful of requests and
its >30% gate flapped run-to-run; this module replaces it as the serving
measurement floor. It replays an **arrival process** — Poisson with mixed
prompt/output length distributions, shared-prefix mix, priority levels,
deadline traffic and mid-flight cancellations, or a recorded trace — against
a :class:`~repro.serving.ServingEngine` through the streaming API
(``submit`` → ``TokenStream``), stamps every token against the engine's
clock, and reports **SLO goodput**: the fraction of offered requests that
completed within a TTFT + inter-token-latency SLO, alongside p50/p99 TTFT,
inter-token gap, queue wait, and shed/cancel/reject counts.

The same generator runs in two modes:

* **wall-clock** — the engine keeps its default ``time.monotonic`` clock;
  arrivals are released as real time passes (the pump sleeps while idle).
  ``benchmarks/serve_load.py`` runs this mode and emits ``BENCH_load.json``.
* **virtual-clock** — the engine is built with a
  :class:`~repro.serving.clock.VirtualClock` and a :class:`VirtualCost`
  model is supplied: the generator advances the clock itself (a fixed cost
  per engine step plus a per-prompt-token prefill surcharge), so every
  deadline / TTFT / queue-wait / shedding path is a pure function of the
  op sequence — tier-1 tests assert EXACT timings with zero sleeps.

Statistics: :func:`run_trials` repeats a workload over per-trial seeds and
:func:`bootstrap_summary` pools the per-request samples, attaching bootstrap
confidence intervals to goodput and to each latency percentile. The CI gate
(``tools/check_bench.py``) keys on goodput **interval overlap** instead of a
point threshold — see DESIGN.md §12 for why that cannot flap the way the
tok/s point gate did.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .api import GenerationRequest, QueueFullError, SamplingParams
from .clock import VirtualClock
from .encoder import EncodeRequest

__all__ = ["SLO", "Workload", "Arrival", "VirtualCost", "RequestRecord",
           "LoadResult", "make_arrivals", "trace_arrivals", "load_trace",
           "run_load", "run_trials", "bootstrap_summary"]


# ------------------------------------------------------------------- specs
@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request service-level objective.

    A request is **good** when it completed normally (``length``/``stop``)
    with ``ttft <= ttft_s`` and every inter-token gap ``<= itl_s``. Shed,
    rejected, and SLO-missing requests all count against goodput; requests
    the generator itself cancels are excluded from the denominator (their
    failure is injected, not the engine's).
    """

    ttft_s: float
    itl_s: float


@dataclasses.dataclass(frozen=True)
class VirtualCost:
    """Deterministic time model for virtual-clock runs: each ``engine_step``
    costs ``decode_step_s`` plus ``prefill_per_token_s`` for every prompt
    token whose request produced its FIRST token this step (prefill happens
    in the step that emits a request's first token)."""

    decode_step_s: float = 0.01
    prefill_per_token_s: float = 0.001
    #: per-token surcharge for prefill-only encode work resolved this step
    #: (read off ``engine.last_step_encode_tokens`` — encode requests emit
    #: no token events to infer it from)
    encode_per_token_s: float = 0.001


@dataclasses.dataclass(frozen=True)
class Workload:
    """Distributional description of an offered load.

    rate_rps            Poisson arrival rate (exponential inter-arrival
                        gaps); ignored when replaying an explicit trace.
    prompt_len          inclusive (lo, hi) uniform range of prompt lengths.
    new_tokens          inclusive (lo, hi) uniform range of max_new_tokens.
    shared_prefix_frac  fraction of requests whose prompt starts with ONE
                        workload-wide ``shared_prefix_len``-token prefix
                        (exercises the PR-5 prefix cache under load).
    sampled_frac        fraction decoding at temperature 0.8 (per-request
                        seed = arrival index); the rest run greedy.
    priorities          admission priority levels, sampled uniformly.
    deadline_frac/deadline_s   fraction carrying an admission deadline.
    cancel_frac         fraction the GENERATOR cancels mid-flight, after
                        ``cancel_after_tokens`` emitted tokens (uniform in
                        [1, cancel_after_tokens]) — exercises slotted
                        cancellation; queued cancels come out of deadline +
                        overload mixes.
    encode_frac         fraction offered as prefill-only EncodeRequests
                        (task ``encode_task``, DESIGN.md §14); 1.0 is a
                        pure encoder workload. The extra RNG draw only
                        happens when the fraction is nonzero, so existing
                        workloads replay bit-identically.
    tenant              route every request of this workload to the named
                        tenant of a multi-tenant engine (None: the plain
                        single-engine submit surface).
    """

    n_requests: int = 32
    rate_rps: float = 10.0
    vocab: int = 256
    prompt_len: tuple[int, int] = (4, 12)
    new_tokens: tuple[int, int] = (2, 8)
    shared_prefix_frac: float = 0.0
    shared_prefix_len: int = 16
    sampled_frac: float = 0.0
    priorities: tuple[int, ...] = (0,)
    deadline_frac: float = 0.0
    deadline_s: Optional[float] = None
    cancel_frac: float = 0.0
    cancel_after_tokens: int = 2
    encode_frac: float = 0.0
    encode_task: str = "classify"
    tenant: Optional[str] = None


@dataclasses.dataclass
class Arrival:
    """One request of the arrival process: absolute release time + the
    fully-resolved request fields (so a trace replays bit-identically)."""

    t: float
    prompt: np.ndarray
    max_new_tokens: int
    sampling: Optional[SamplingParams] = None
    priority: int = 0
    deadline_s: Optional[float] = None
    cancel_after_tokens: Optional[int] = None
    task: Optional[str] = None      # encode task; None = generation request
    tenant: Optional[str] = None    # multi-tenant routing label

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


def make_arrivals(w: Workload, seed: int = 0) -> list[Arrival]:
    """Sample a concrete arrival list from ``w`` — deterministic per
    (workload, seed), so a virtual-clock replay is exactly repeatable."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, w.vocab, w.shared_prefix_len).astype(np.int32)
    t = 0.0
    out: list[Arrival] = []
    for i in range(w.n_requests):
        t += float(rng.exponential(1.0 / w.rate_rps))
        plen = int(rng.integers(w.prompt_len[0], w.prompt_len[1] + 1))
        if rng.random() < w.shared_prefix_frac:
            tail = rng.integers(1, w.vocab, max(plen, 1)).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(1, w.vocab, max(plen, 1)).astype(np.int32)
        sampling = None
        if rng.random() < w.sampled_frac:
            sampling = SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                                      seed=i)
        deadline = (w.deadline_s if w.deadline_s is not None
                    and rng.random() < w.deadline_frac else None)
        cancel = (int(rng.integers(1, w.cancel_after_tokens + 1))
                  if rng.random() < w.cancel_frac else None)
        # guarded draw: workloads with encode_frac=0 consume the exact RNG
        # sequence they did before encode traffic existed
        task = (w.encode_task if w.encode_frac
                and rng.random() < w.encode_frac else None)
        out.append(Arrival(
            t=t, prompt=prompt,
            max_new_tokens=int(rng.integers(w.new_tokens[0],
                                            w.new_tokens[1] + 1)),
            sampling=sampling,
            priority=int(rng.choice(w.priorities)),
            deadline_s=deadline, cancel_after_tokens=cancel,
            task=task, tenant=w.tenant))
    return out


def trace_arrivals(trace: Sequence, w: Workload, seed: int = 0
                   ) -> list[Arrival]:
    """Recorded-trace arrival process: ``trace`` is a sequence of floats
    (arrival offsets in seconds) or dicts with ``t`` plus optional
    per-request overrides (``prompt_len``, ``max_new_tokens``, ``priority``,
    ``deadline_s``, ``cancel_after_tokens``, ``temperature``). Fields a
    trace entry does not pin are sampled from ``w`` (seeded) — replaying the
    same trace with the same workload + seed yields identical requests."""
    rng = np.random.default_rng(seed)
    out: list[Arrival] = []
    for i, entry in enumerate(trace):
        e = {"t": float(entry)} if not isinstance(entry, dict) else dict(entry)
        plen = int(e.get("prompt_len",
                         rng.integers(w.prompt_len[0], w.prompt_len[1] + 1)))
        prompt = rng.integers(1, w.vocab, max(plen, 1)).astype(np.int32)
        temp = e.get("temperature", 0.0)
        sampling = (SamplingParams(temperature=float(temp), seed=i)
                    if temp else None)
        out.append(Arrival(
            t=float(e["t"]), prompt=prompt,
            max_new_tokens=int(e.get("max_new_tokens",
                                     rng.integers(w.new_tokens[0],
                                                  w.new_tokens[1] + 1))),
            sampling=sampling,
            priority=int(e.get("priority", 0)),
            deadline_s=e.get("deadline_s"),
            cancel_after_tokens=e.get("cancel_after_tokens")))
    out.sort(key=lambda a: a.t)
    return out


def load_trace(path: str) -> list:
    """Read a recorded trace (JSON list of offsets or entry dicts)."""
    with open(path) as f:
        trace = json.load(f)
    if not isinstance(trace, list):
        raise ValueError(f"trace {path} must be a JSON list, "
                         f"got {type(trace).__name__}")
    return trace


# ----------------------------------------------------------------- records
#: terminal states a record can reach; engine FINISH_REASONS plus the
#: encode-path ``done`` and the generator-side ``rejected`` (QueueFullError
#: backpressure — including tenant quota — at submit).
RECORD_OUTCOMES = ("length", "stop", "done", "cancelled", "shed", "rejected")


@dataclasses.dataclass
class RequestRecord:
    """Everything the generator observed about one offered request."""

    index: int                       # position in the arrival list
    arrival_t: float                 # intended release time
    submit_t: float                  # actual submit stamp (engine clock)
    prompt_len: int
    max_new_tokens: int
    priority: int
    deadline_s: Optional[float]
    injected_cancel: bool            # generator planned to cancel this one
    task: Optional[str] = None       # encode task; None = generation
    tenant: Optional[str] = None
    rid: int = -1
    token_times: list = dataclasses.field(default_factory=list)
    tokens: list = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    finish_t: Optional[float] = None
    queue_wait_s: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if not self.token_times:
            return None
        return self.token_times[0] - self.submit_t

    @property
    def gaps_s(self) -> list[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def encode_latency_s(self) -> Optional[float]:
        """Submit → result for encode records (the one-shot TTFT analogue)."""
        if self.task is None or self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    def slo_ok(self, slo: SLO) -> bool:
        if self.task is not None:    # encode: one result, judged like a TTFT
            return (self.finish_reason == "done"
                    and self.encode_latency_s is not None
                    and self.encode_latency_s <= slo.ttft_s)
        if self.finish_reason not in ("length", "stop"):
            return False
        if self.ttft_s is None or self.ttft_s > slo.ttft_s:
            return False
        return all(g <= slo.itl_s for g in self.gaps_s)


def _pcts_ms(samples: list[float]) -> dict:
    if not samples:
        return {}
    arr = np.asarray(samples, np.float64) * 1e3
    if len(arr) < 2:                 # match ServeMetrics' sub-2-sample guard
        return {"p50_ms": float(arr[0]), "p99_ms": float(arr[0])}
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99))}


@dataclasses.dataclass
class LoadResult:
    """One trial's outcome: per-request records + pump accounting."""

    records: list[RequestRecord]
    duration_s: float
    steps: int

    def counted(self) -> list[RequestRecord]:
        """Records in the goodput denominator (injected cancels excluded)."""
        return [r for r in self.records if not r.injected_cancel]

    def summary(self, slo: SLO) -> dict:
        recs = self.records
        counted = self.counted()
        good = [r for r in counted if r.slo_ok(slo)]
        by = {k: sum(r.finish_reason == k for r in recs)
              for k in RECORD_OUTCOMES}
        out = {
            "n_offered": len(recs),
            "n_counted": len(counted),
            "n_good": len(good),
            "goodput": len(good) / max(len(counted), 1),
            "n_completed": by["length"] + by["stop"] + by["done"],
            "n_shed": by["shed"],
            "n_cancelled": by["cancelled"],
            "n_rejected": by["rejected"],
            "duration_s": self.duration_s,
            "steps": self.steps,
        }
        if self.duration_s > 0:
            out["goodput_rps"] = len(good) / self.duration_s
        for name, samples in (
                ("ttft", [r.ttft_s for r in recs if r.ttft_s is not None]),
                ("itl", [g for r in recs for g in r.gaps_s]),
                ("queue_wait", [r.queue_wait_s for r in recs
                                if r.queue_wait_s is not None]),
                ("encode_latency", [r.encode_latency_s for r in recs
                                    if r.encode_latency_s is not None])):
            for k, v in _pcts_ms(samples).items():
                out[f"{name}_{k}"] = v
        return out


# -------------------------------------------------------------------- pump
def run_load(engine, arrivals: Sequence[Arrival], *,
             cost: Optional[VirtualCost] = None,
             max_steps: int = 200_000,
             idle_sleep_s: float = 0.002,
             sleep: Callable[[float], None] = time.sleep) -> LoadResult:
    """Closed-loop replay of ``arrivals`` against ``engine``.

    With ``cost=None`` (wall-clock mode) the engine's own clock advances by
    itself and the pump sleeps while waiting for the next arrival. With a
    :class:`VirtualCost` the engine MUST have been built with a
    :class:`VirtualClock` — the generator advances it deterministically:
    idle gaps jump straight to the next arrival, and each ``engine_step``
    charges the cost model. Token stamps are taken AFTER the step's cost is
    applied, so a virtual TTFT includes the prefill step that produced the
    first token, exactly like a wall-clock TTFT includes its real duration.
    """
    clock = engine.clock
    virtual = cost is not None
    if virtual and not isinstance(clock, VirtualClock):
        raise TypeError("virtual-clock mode needs an engine built with "
                        "clock=VirtualClock(...); this engine's clock is "
                        f"{clock!r}")
    arrivals = sorted(arrivals, key=lambda a: a.t)
    records: list[RequestRecord] = []
    by_rid: dict[int, RequestRecord] = {}
    streams: dict[int, object] = {}
    cancel_at: dict[int, int] = {}       # rid -> cancel after N tokens
    idx, steps = 0, 0
    t_start = clock()

    def submit_due(now: float) -> None:
        nonlocal idx
        while idx < len(arrivals) and arrivals[idx].t <= now:
            a = arrivals[idx]
            idx += 1
            if a.task is not None:
                req = EncodeRequest(tokens=a.prompt, task=a.task,
                                    priority=a.priority,
                                    deadline_s=a.deadline_s)
            else:
                req = GenerationRequest(
                    prompt=a.prompt, max_new_tokens=a.max_new_tokens,
                    sampling=a.sampling, priority=a.priority,
                    deadline_s=a.deadline_s)
            rec = RequestRecord(
                index=idx - 1, arrival_t=a.t, submit_t=clock(),
                prompt_len=a.prompt_len, max_new_tokens=a.max_new_tokens,
                priority=a.priority, deadline_s=a.deadline_s,
                injected_cancel=a.cancel_after_tokens is not None,
                task=a.task, tenant=a.tenant)
            records.append(rec)
            # multi-tenant engines take the routing label; the single-engine
            # surface has no tenant kwarg, so only pass it when set
            kw = {} if a.tenant is None else {"tenant": a.tenant}
            try:
                if a.task is not None:
                    stream = engine.submit_encode(req, **kw)
                else:
                    stream = engine.submit(req, **kw)
            except QueueFullError:
                rec.rid = req.rid
                rec.finish_reason = "rejected"
                rec.finish_t = clock()
                continue
            rec.rid = req.rid
            by_rid[req.rid] = rec
            streams[req.rid] = stream
            if a.cancel_after_tokens is not None:
                cancel_at[req.rid] = a.cancel_after_tokens

    while True:
        now = clock()
        submit_due(now)
        if not engine.scheduler.has_work:
            if idx >= len(arrivals):
                break                      # drained and nothing left to offer
            gap = arrivals[idx].t - now
            if virtual:
                clock.advance_to(arrivals[idx].t)
            elif gap > 0:
                sleep(min(gap, idle_sleep_s))
            continue
        if steps >= max_steps:
            raise RuntimeError(
                f"run_load: exceeded max_steps={max_steps} with "
                f"{len(arrivals) - idx} arrival(s) unreleased and work "
                "still pending — engine stalled or cost/rate mismatch")
        events = engine.engine_step()
        steps += 1
        if virtual:
            prefill_tokens = sum(
                rec.prompt_len for rid in {r for r, _ in events}
                if (rec := by_rid.get(rid)) is not None
                and not rec.token_times)
            encode_tokens = getattr(engine, "last_step_encode_tokens", 0)
            clock.advance(cost.decode_step_s
                          + cost.prefill_per_token_s * prefill_tokens
                          + cost.encode_per_token_s * encode_tokens)
        now = clock()
        for rid, tok in events:
            rec = by_rid.get(rid)
            if rec is None:        # warmup leftovers: not ours to score
                continue
            rec.token_times.append(now)
            rec.tokens.append(int(tok))
        for rid, after in list(cancel_at.items()):
            rec = by_rid[rid]
            if rec.finish_reason is None and len(rec.token_times) >= after:
                streams[rid].cancel()
                del cancel_at[rid]
        for req in engine.pop_done():
            rec = by_rid.get(req.rid)
            if rec is None:        # e.g. warmup leftovers: not ours to score
                continue
            rec.finish_reason = req.finish_reason
            rec.finish_t = now
            rec.queue_wait_s = req.queue_wait_s
            streams.pop(req.rid, None)
            cancel_at.pop(req.rid, None)
    return LoadResult(records=records, duration_s=clock() - t_start,
                      steps=steps)


def run_trials(make_engine: Callable[[], object], w: Workload, *,
               n_trials: int, cost: Optional[VirtualCost] = None,
               base_seed: int = 0, trace: Optional[Sequence] = None,
               max_steps: int = 200_000) -> list[LoadResult]:
    """Repeat the workload over per-trial arrival seeds, each against a
    fresh engine from ``make_engine`` (which must install a VirtualClock
    when ``cost`` is given). Trial ``i`` uses seed ``base_seed + i`` — the
    trial set is reproducible as a whole."""
    results = []
    for i in range(n_trials):
        arrivals = (trace_arrivals(trace, w, seed=base_seed + i)
                    if trace is not None
                    else make_arrivals(w, seed=base_seed + i))
        results.append(run_load(make_engine(), arrivals, cost=cost,
                                max_steps=max_steps))
    return results


# ---------------------------------------------------------------- boot CIs
def _boot_ci(samples: np.ndarray, stat: Callable[[np.ndarray], float],
             rng: np.random.Generator, n_boot: int, level: float) -> dict:
    """Percentile-bootstrap CI of ``stat`` over ``samples``."""
    point = float(stat(samples))
    n = len(samples)
    stats = np.array([stat(samples[rng.integers(0, n, n)])
                      for _ in range(n_boot)])
    alpha = 100.0 * (1.0 - level) / 2.0
    return {"mean": point,
            "lo": float(np.percentile(stats, alpha)),
            "hi": float(np.percentile(stats, 100.0 - alpha))}


def bootstrap_summary(results: Sequence[LoadResult], slo: SLO, *,
                      n_boot: int = 400, seed: int = 0,
                      level: float = 0.95) -> dict:
    """Pool per-request samples across trials and attach bootstrap CIs.

    ``goodput`` resamples the per-request SLO indicators; each latency
    percentile resamples its pooled sample set and recomputes the
    percentile. Deterministic per (results, seed) — the CI gate can be
    re-run bit-identically."""
    rng = np.random.default_rng(seed)
    indicators = np.array([1.0 if r.slo_ok(slo) else 0.0
                           for res in results for r in res.counted()])
    out: dict = {
        "n_trials": len(results),
        "slo": {"ttft_s": slo.ttft_s, "itl_s": slo.itl_s},
        "n_boot": n_boot,
        "level": level,
    }
    for k in ("n_offered", "n_counted", "n_good", "n_completed", "n_shed",
              "n_cancelled", "n_rejected", "steps"):
        out[k] = int(sum(res.summary(slo)[k] for res in results))
    out["duration_s"] = float(sum(res.duration_s for res in results))
    if len(indicators):
        out["goodput"] = _boot_ci(indicators, np.mean, rng, n_boot, level)
    tenants = sorted({r.tenant for res in results for r in res.records
                      if r.tenant is not None})
    if tenants:
        # per-tenant point estimates (no CIs: the fair-share gate compares
        # whole-tenant counts, which are deterministic per seed set)
        out["by_tenant"] = {}
        for name in tenants:
            cnt = [r for res in results for r in res.counted()
                   if r.tenant == name]
            good = sum(r.slo_ok(slo) for r in cnt)
            comp = sum(r.finish_reason in ("length", "stop", "done")
                       for r in cnt)
            out["by_tenant"][name] = {
                "n_counted": len(cnt), "n_completed": comp, "n_good": good,
                "goodput": good / max(len(cnt), 1)}
    pools = {
        "ttft": [r.ttft_s for res in results for r in res.records
                 if r.ttft_s is not None],
        "itl": [g for res in results for r in res.records for g in r.gaps_s],
        "queue_wait": [r.queue_wait_s for res in results for r in res.records
                       if r.queue_wait_s is not None],
        "encode_latency": [r.encode_latency_s for res in results
                           for r in res.records
                           if r.encode_latency_s is not None],
    }
    for name, samples in pools.items():
        if not samples:
            continue
        arr = np.asarray(samples, np.float64) * 1e3
        for p in (50, 99):
            out[f"{name}_p{p}_ms"] = _boot_ci(
                arr, lambda a, p=p: float(np.percentile(a, p))
                if len(a) > 1 else float(a[0]), rng, n_boot, level)
    return out
