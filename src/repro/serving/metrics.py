"""Latency/throughput recorder for the serving engine (DESIGN.md §7/§10).

Records (kind, seconds, tokens) step events — kind is 'prefill' or 'decode'
— plus per-request wait samples ('ttft': submit → first emitted token,
'queue_wait': submit → slot admission), and summarizes tokens/sec, p50/p99
step latency per kind and p50/p99 of the per-request waits. Wait samples are
kept OUT of the busy-time denominator — queueing is not compute, so it must
not deflate tokens/sec. Pure host-side bookkeeping; never touches device
state.
"""
from __future__ import annotations

import time

import numpy as np

#: per-request wait kinds recorded via ``record_wait``
WAIT_KINDS = ("ttft", "queue_wait")


def _pcts(lat: np.ndarray) -> tuple[float, float]:
    """p50/p99 with the sub-2-sample guard: interpolating percentiles over a
    lone sample is meaningless and np.percentile warns/raises on degenerate
    inputs depending on dtype — report the sample as every percentile."""
    if len(lat) < 2:
        return float(lat[0] * 1e3), float(lat[0] * 1e3)
    return (float(np.percentile(lat, 50) * 1e3),
            float(np.percentile(lat, 99) * 1e3))


class ServeMetrics:
    def __init__(self):
        self._events: list[tuple[str, float, int]] = []
        self._waits: list[tuple[str, float]] = []
        self._t0 = time.perf_counter()

    def record(self, kind: str, seconds: float, tokens: int) -> None:
        self._events.append((kind, seconds, tokens))

    def record_wait(self, kind: str, seconds: float) -> None:
        """Per-request wait sample: 'ttft' or 'queue_wait'."""
        assert kind in WAIT_KINDS, kind
        self._waits.append((kind, seconds))

    def _kind(self, kind: str) -> tuple[np.ndarray, int]:
        lat = np.array([s for k, s, _ in self._events if k == kind])
        toks = sum(t for k, _, t in self._events if k == kind)
        return lat, toks

    def summary(self) -> dict:
        out: dict = {"wall_s": time.perf_counter() - self._t0}
        total_tokens = 0
        for kind in ("prefill", "decode"):
            lat, toks = self._kind(kind)
            total_tokens += toks
            if len(lat) == 0:
                continue
            out[f"{kind}_steps"] = len(lat)
            out[f"{kind}_tokens"] = toks
            p50, p99 = _pcts(lat)
            out[f"{kind}_p50_ms"] = p50
            out[f"{kind}_p99_ms"] = p99
            out[f"{kind}_mean_ms"] = float(lat.mean() * 1e3)
        out["total_tokens"] = total_tokens
        busy = sum(s for _, s, _ in self._events)
        out["tokens_per_s"] = total_tokens / max(busy, 1e-9)
        for kind in WAIT_KINDS:
            lat = np.array([s for k, s in self._waits if k == kind])
            if len(lat) == 0:
                continue
            p50, p99 = _pcts(lat)
            out[f"{kind}_n"] = len(lat)
            out[f"{kind}_p50_ms"] = p50
            out[f"{kind}_p99_ms"] = p99
        return out

    def report(self) -> str:
        s = self.summary()
        parts = [f"{s['total_tokens']} tok @ {s['tokens_per_s']:.1f} tok/s"]
        for kind in ("prefill", "decode"):
            if f"{kind}_steps" in s:
                parts.append(
                    f"{kind}: {s[f'{kind}_steps']} steps "
                    f"p50 {s[f'{kind}_p50_ms']:.1f}ms "
                    f"p99 {s[f'{kind}_p99_ms']:.1f}ms")
        for kind in WAIT_KINDS:
            if f"{kind}_n" in s:
                parts.append(
                    f"{kind}: p50 {s[f'{kind}_p50_ms']:.1f}ms "
                    f"p99 {s[f'{kind}_p99_ms']:.1f}ms")
        return " | ".join(parts)
