"""Latency/throughput recorder for the serving engine (DESIGN.md §7).

Records (kind, seconds, tokens) events — kind is 'prefill' or 'decode' — and
summarizes tokens/sec plus p50/p99 step latency per kind. Pure host-side
bookkeeping; never touches device state.
"""
from __future__ import annotations

import time

import numpy as np


class ServeMetrics:
    def __init__(self):
        self._events: list[tuple[str, float, int]] = []
        self._t0 = time.perf_counter()

    def record(self, kind: str, seconds: float, tokens: int) -> None:
        self._events.append((kind, seconds, tokens))

    def _kind(self, kind: str) -> tuple[np.ndarray, int]:
        lat = np.array([s for k, s, _ in self._events if k == kind])
        toks = sum(t for k, _, t in self._events if k == kind)
        return lat, toks

    def summary(self) -> dict:
        out: dict = {"wall_s": time.perf_counter() - self._t0}
        total_tokens = 0
        for kind in ("prefill", "decode"):
            lat, toks = self._kind(kind)
            total_tokens += toks
            if len(lat) == 0:
                continue
            out[f"{kind}_steps"] = len(lat)
            out[f"{kind}_tokens"] = toks
            # sub-2-sample windows (tiny --quick bench runs): interpolating
            # percentiles is meaningless and np.percentile warns/raises on
            # degenerate inputs depending on dtype — report the lone sample
            # as every percentile instead of crashing the bench job.
            if len(lat) < 2:
                p50 = p99 = float(lat[0] * 1e3)
            else:
                p50 = float(np.percentile(lat, 50) * 1e3)
                p99 = float(np.percentile(lat, 99) * 1e3)
            out[f"{kind}_p50_ms"] = p50
            out[f"{kind}_p99_ms"] = p99
            out[f"{kind}_mean_ms"] = float(lat.mean() * 1e3)
        out["total_tokens"] = total_tokens
        busy = sum(s for _, s, _ in self._events)
        out["tokens_per_s"] = total_tokens / max(busy, 1e-9)
        return out

    def report(self) -> str:
        s = self.summary()
        parts = [f"{s['total_tokens']} tok @ {s['tokens_per_s']:.1f} tok/s"]
        for kind in ("prefill", "decode"):
            if f"{kind}_steps" in s:
                parts.append(
                    f"{kind}: {s[f'{kind}_steps']} steps "
                    f"p50 {s[f'{kind}_p50_ms']:.1f}ms "
                    f"p99 {s[f'{kind}_p99_ms']:.1f}ms")
        return " | ".join(parts)
