"""Latency/throughput recorder for the serving engine (DESIGN.md §7/§10/§11).

Records (kind, seconds, tokens) step events — kind is 'prefill', 'decode' or
'encode' (the prefill-only request path, DESIGN.md §14) — plus per-request
wait samples ('ttft': submit → first emitted token, 'queue_wait': submit →
slot admission, 'encode_latency': submit → encode result), and summarizes
tokens/sec, p50/p99 step latency per kind and p50/p99 of the per-request
waits. Wait samples are kept OUT of the busy-time denominator — queueing is
not compute, so it must not deflate tokens/sec. Pure host-side bookkeeping;
never touches device state.

Multi-tenancy: ``record``/``record_wait`` take an optional ``tenant`` label.
Labeled events additionally roll up into plain-integer per-(tenant, kind)
counters — tokens and sample counts only, never sample lists — surfaced
under the summary's ``by_label`` key, so a shared-process deployment
(serving/tenants.py) can prove per-tenant progress without per-tenant
metric objects.

Memory discipline: a long-lived engine records events forever, so the raw
sample lists are bounded deques (``window`` samples per stream, default
65536; ``None`` keeps everything for offline analysis). Percentiles and
tokens/sec then describe the most recent window. ``pop_summary()`` is the
drain form — summarize-and-reset, the same non-leaking consumption pattern
as ``Scheduler.pop_done()`` — and drains the labeled counters too.

Prefix-cache counters (DESIGN.md §11) are plain integers (never grow):
``record_prefix(reused, prompt_tokens)`` per admission feeds the
``prefix_hit_rate`` / ``prefill_tokens_saved`` summary keys.

KV memory gauges (DESIGN.md §15): a paged engine calls ``update_kv`` with
the block pool's ``stats()`` dict each step — last-write-wins gauges
(bytes in use, blocks allocated/free, prefix blocks shared by reference,
COW forks, evictions), surfaced under the summary's ``kv`` key and drained
by ``pop_summary()`` like everything else.

First-vs-steady split (DESIGN.md §16): the FIRST step of each kind an
engine ever runs pays jit trace + compile; ``{kind}_first_ms`` reports that
lifetime-first latency and ``{kind}_steady_p50_ms`` the p50 with it
excluded, so the cold-start cut from engine pre-warming is directly visible
next to the steady state. Both are LIFETIME values — ``pop_summary()``
drains the sample windows but never forgets which step was first.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import numpy as np

from .clock import Clock

#: step-event kinds recorded via ``record``
STEP_KINDS = ("prefill", "decode", "encode")

#: per-request wait kinds recorded via ``record_wait``
WAIT_KINDS = ("ttft", "queue_wait", "encode_latency")

#: default bounded-window length (samples kept per stream)
DEFAULT_WINDOW = 65536


def _pcts(lat: np.ndarray) -> tuple[float, float]:
    """p50/p99 with the sub-2-sample guard: interpolating percentiles over a
    lone sample is meaningless and np.percentile warns/raises on degenerate
    inputs depending on dtype — report the sample as every percentile (and
    refuse an empty window outright: callers skip those)."""
    if len(lat) == 0:
        raise ValueError("percentiles of an empty window")
    if len(lat) < 2:
        return float(lat[0] * 1e3), float(lat[0] * 1e3)
    return (float(np.percentile(lat, 50) * 1e3),
            float(np.percentile(lat, 99) * 1e3))


class ServeMetrics:
    def __init__(self, window: Optional[int] = DEFAULT_WINDOW,
                 clock: Clock = time.perf_counter):
        # ``clock`` stamps the wall_s window (DESIGN.md §12): the engine
        # injects its own clock so a VirtualClock run reports virtual wall
        # time; the standalone default stays perf_counter, unchanged.
        self.window = window
        self._clock = clock
        # lifetime (never reset): kind -> first recorded seconds, and
        # kind -> total events ever recorded — together they tell summary()
        # whether the current window still CONTAINS the lifetime-first
        # sample (window count == lifetime count) and must exclude it from
        # the steady percentile.
        self._first: dict = {}
        self._lifetime: dict = {}
        self._reset()

    def _reset(self) -> None:
        self._events: deque = deque(maxlen=self.window)
        self._waits: deque = deque(maxlen=self.window)
        self._t0 = self._clock()
        self._prefix_lookups = 0
        self._prefix_hits = 0
        self._prefix_reused = 0
        self._prefix_prompt_tokens = 0
        # (tenant, kind) -> [events, tokens] and (tenant, wait-kind) -> n:
        # plain counters so N tenants cost O(N) ints, not N sample windows.
        self._label_steps: dict[tuple[str, str], list[int]] = {}
        self._label_waits: dict[tuple[str, str], int] = {}
        # KV memory gauges (paged engines): last-write-wins snapshot dict
        self._kv: dict = {}

    def record(self, kind: str, seconds: float, tokens: int,
               tenant: Optional[str] = None) -> None:
        assert kind in STEP_KINDS, kind
        self._events.append((kind, seconds, tokens))
        if kind not in self._first:
            self._first[kind] = seconds
        self._lifetime[kind] = self._lifetime.get(kind, 0) + 1
        if tenant is not None:
            cell = self._label_steps.setdefault((tenant, kind), [0, 0])
            cell[0] += 1
            cell[1] += tokens

    def record_wait(self, kind: str, seconds: float,
                    tenant: Optional[str] = None) -> None:
        """Per-request wait sample: 'ttft', 'queue_wait', 'encode_latency'."""
        assert kind in WAIT_KINDS, kind
        self._waits.append((kind, seconds))
        if tenant is not None:
            key = (tenant, kind)
            self._label_waits[key] = self._label_waits.get(key, 0) + 1

    def update_kv(self, gauges: dict) -> None:
        """Overwrite the KV memory gauges (``BlockPool.stats()``): gauges
        describe CURRENT state, so last write wins — no sample windows."""
        self._kv = dict(gauges)

    def record_prefix(self, reused: int, prompt_tokens: int) -> None:
        """One admission's prefix-cache outcome: ``reused`` prompt tokens
        restored from cache out of ``prompt_tokens`` total."""
        self._prefix_lookups += 1
        if reused > 0:
            self._prefix_hits += 1
        self._prefix_reused += reused
        self._prefix_prompt_tokens += prompt_tokens

    def _kind(self, kind: str) -> tuple[np.ndarray, int]:
        lat = np.array([s for k, s, _ in self._events if k == kind])
        toks = sum(t for k, _, t in self._events if k == kind)
        return lat, toks

    def _by_label(self) -> dict:
        """Per-tenant rollups keyed ``'<tenant>/<kind>'`` (string keys so
        the dict survives a JSON round-trip in benchmark artifacts)."""
        out: dict = {}
        for (tenant, kind), (steps, toks) in sorted(self._label_steps.items()):
            out[f"{tenant}/{kind}"] = {"steps": steps, "tokens": toks}
        for (tenant, kind), n in sorted(self._label_waits.items()):
            out.setdefault(f"{tenant}/{kind}", {})["n"] = n
        return out

    def summary(self) -> dict:
        out: dict = {"wall_s": self._clock() - self._t0}
        total_tokens = 0
        for kind in STEP_KINDS:
            lat, toks = self._kind(kind)
            total_tokens += toks
            if len(lat) == 0:
                continue
            out[f"{kind}_steps"] = len(lat)
            out[f"{kind}_tokens"] = toks
            p50, p99 = _pcts(lat)
            out[f"{kind}_p50_ms"] = p50
            out[f"{kind}_p99_ms"] = p99
            out[f"{kind}_mean_ms"] = float(lat.mean() * 1e3)
            out[f"{kind}_first_ms"] = float(self._first[kind] * 1e3)
            # steady = the window minus the LIFETIME-first sample, which is
            # at index 0 exactly when the window holds every event ever
            # recorded for this kind (no pop_summary, no deque trim since)
            steady = (lat[1:] if self._lifetime.get(kind) == len(lat)
                      else lat)
            if len(steady):
                out[f"{kind}_steady_p50_ms"] = _pcts(steady)[0]
        # lifetime-first latencies outlive pop_summary() windows: surface
        # them even when the current window holds no samples of that kind
        for kind, first in self._first.items():
            out.setdefault(f"{kind}_first_ms", float(first * 1e3))
        out["total_tokens"] = total_tokens
        busy = sum(s for _, s, _ in self._events)
        out["tokens_per_s"] = total_tokens / max(busy, 1e-9)
        for kind in WAIT_KINDS:
            lat = np.array([s for k, s in self._waits if k == kind])
            if len(lat) == 0:
                continue
            p50, p99 = _pcts(lat)
            out[f"{kind}_n"] = len(lat)
            out[f"{kind}_p50_ms"] = p50
            out[f"{kind}_p99_ms"] = p99
        if self._prefix_lookups:
            out["prefix_lookups"] = self._prefix_lookups
            out["prefix_hit_rate"] = self._prefix_hits / self._prefix_lookups
            out["prefill_tokens_saved"] = self._prefix_reused
            out["prefix_reuse_frac"] = (
                self._prefix_reused / max(self._prefix_prompt_tokens, 1))
        if self._label_steps or self._label_waits:
            out["by_label"] = self._by_label()
        if self._kv:
            out["kv"] = dict(self._kv)
        return out

    def pop_summary(self) -> dict:
        """Summarize-and-reset: the bounded-memory way to consume metrics
        from a long-lived engine (windows, per-tenant counters and the wall
        clock all restart)."""
        out = self.summary()
        self._reset()
        return out

    def report(self) -> str:
        s = self.summary()
        parts = [f"{s['total_tokens']} tok @ {s['tokens_per_s']:.1f} tok/s"]
        for kind in STEP_KINDS:
            if f"{kind}_steps" in s:
                parts.append(
                    f"{kind}: {s[f'{kind}_steps']} steps "
                    f"p50 {s[f'{kind}_p50_ms']:.1f}ms "
                    f"p99 {s[f'{kind}_p99_ms']:.1f}ms")
        for kind in WAIT_KINDS:
            if f"{kind}_n" in s:
                parts.append(
                    f"{kind}: p50 {s[f'{kind}_p50_ms']:.1f}ms "
                    f"p99 {s[f'{kind}_p99_ms']:.1f}ms")
        if "prefix_hit_rate" in s:
            parts.append(
                f"prefix: {s['prefix_hit_rate']:.0%} hit, "
                f"{s['prefill_tokens_saved']} tok saved")
        for label, cell in s.get("by_label", {}).items():
            if "tokens" in cell:
                parts.append(f"{label}: {cell['tokens']} tok "
                             f"in {cell['steps']} steps")
        kv = s.get("kv")
        if kv:
            parts.append(
                f"kv: {kv.get('kv_bytes_in_use', 0) / 1024:.1f}KiB "
                f"({kv.get('blocks_in_use', 0)}/{kv.get('blocks_total', 0)} "
                f"blocks, {kv.get('prefix_blocks', 0)} prefix, "
                f"{kv.get('cow_forks', 0)} forks)")
        return " | ".join(parts)
