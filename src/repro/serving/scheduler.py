"""Request scheduler: FIFO queue + fixed slot table with continuous refill.

Continuous-batching-lite (DESIGN.md §7): the engine decodes one token per
step for every occupied slot; whenever a request finishes, its slot is
refilled from the queue on the next ``admit`` — no global batch barrier, so
short requests never wait for long ones.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None
    rid: int = -1                   # assigned by the scheduler on submit


class Scheduler:
    """Owns the queue, the slot table and request lifecycle bookkeeping."""

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = slots
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.done: list[Request] = []
        self._next_id = 0

    # ------------------------------------------------------------- lifecycle
    def assign_id(self, req: Request) -> Request:
        """Give a request its rid without enqueueing it (the engine assigns
        before validation so rejections reference a real request id)."""
        if req.rid < 0:
            req.rid = self._next_id
            self._next_id += 1
        return req

    def submit(self, req: Request) -> Request:
        self.assign_id(req)
        self.queue.append(req)
        return req

    def admit(self) -> list[tuple[int, Request]]:
        """Fill every free slot from the queue; returns the new placements."""
        placed = []
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                placed.append((s, req))
        return placed

    def complete(self, slot: int) -> Request:
        req = self.active[slot]
        assert req is not None, f"slot {slot} is empty"
        self.active[slot] = None
        self.done.append(req)
        return req

    # ------------------------------------------------------------- queries
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)

    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.active) if r is not None]
