"""Request scheduler: priority queue + fixed slot table with continuous
refill (DESIGN.md §7, admission policy §10).

Continuous-batching-lite: the engine decodes one token per step for every
occupied slot; whenever a request finishes, its slot is refilled from the
queue on the next ``admit`` — no global batch barrier, so short requests
never wait for long ones.

Admission policy (DESIGN.md §10):

* **priority** — higher ``GenerationRequest.priority`` admits first; FIFO
  within a priority level (a monotone sequence number breaks heap ties).
* **bounded queue** — ``max_queue`` caps pending depth; ``submit`` raises
  :class:`~repro.serving.api.QueueFullError` (backpressure) instead of
  growing without bound under overload.
* **deadline shedding** — a request whose ``deadline_s`` elapsed before a
  slot freed up is shed at ``admit`` time (never decoded); the engine drains
  ``pop_shed()`` each step and finalizes those with ``finish_reason='shed'``.
* **drain semantics** — completed requests accumulate in ``done`` only until
  ``pop_done()`` is called, so a long-lived engine does not leak every
  request it ever served.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional

from .api import (GenerationRequest, QueueFullError,  # noqa: F401
                  Request)                            # compat re-export


class Scheduler:
    """Owns the queue, the slot table and request lifecycle bookkeeping."""

    def __init__(self, slots: int, max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, "
                             f"got {max_queue}")
        self.slots = slots
        self.max_queue = max_queue
        self._clock = clock
        self._heap: list[tuple[int, int, GenerationRequest]] = []
        self._seq = itertools.count()        # FIFO within a priority level
        self.active: list[Optional[GenerationRequest]] = [None] * slots
        self.done: list[GenerationRequest] = []
        self._shed: list[GenerationRequest] = []
        # rid source: a shareable counter OBJECT, not a plain int — a
        # ReplicaSet (serving/replicas.py) points every member engine's
        # scheduler at ONE counter so a rid names a request fleet-wide
        # (n>1 fanout children draw from a member's own scheduler, so an
        # unshared per-engine int would collide across replicas).
        self._ids = itertools.count()

    # ------------------------------------------------------------- lifecycle
    def assign_id(self, req: GenerationRequest) -> GenerationRequest:
        """Give a request its rid without enqueueing it (the engine assigns
        before validation so rejections reference a real request id)."""
        if req.rid < 0:
            req.rid = next(self._ids)
        return req

    def submit(self, req: GenerationRequest) -> GenerationRequest:
        self.assign_id(req)
        if self.max_queue is not None and self.queue_depth >= self.max_queue:
            # deadline-expired entries waiting for a slot are already dead —
            # shed them NOW instead of letting them hold queue_depth and
            # bounce live traffic with QueueFullError (they used to be shed
            # only inside admit(), which never runs while every slot is busy)
            self._shed_expired()
        if self.max_queue is not None and self.queue_depth >= self.max_queue:
            raise QueueFullError(
                f"request {req.rid}: queue full ({self.queue_depth}/"
                f"{self.max_queue} pending) — retry or raise max_queue")
        req.submit_t = self._clock()
        heapq.heappush(self._heap, (-req.priority, next(self._seq), req))
        return req

    def _expired(self, req: GenerationRequest, now: float) -> bool:
        return (req.deadline_s is not None and req.submit_t is not None
                and now - req.submit_t > req.deadline_s)

    def _shed_expired(self) -> int:
        """Move every deadline-expired queued request into ``pop_shed()``;
        returns how many were shed. The engine finalizes them on its next
        step."""
        now = self._clock()
        keep = [item for item in self._heap if not self._expired(item[2], now)]
        shed = len(self._heap) - len(keep)
        if shed:
            self._shed.extend(item[2] for item in self._heap
                              if self._expired(item[2], now))
            self._heap = keep
            heapq.heapify(self._heap)
        return shed

    def cancel(self, rid: int) -> Optional[GenerationRequest]:
        """Cancel a QUEUED request: the heap entry is removed EAGERLY (a
        lazy tombstone would outlive ``max_queue`` accounting and leak
        prompts while every slot is busy). Returns the request, or None when
        ``rid`` is not queued — active-slot cancellation is the engine's job
        (it owns the KV state that must be freed)."""
        for i, (_, _, req) in enumerate(self._heap):
            if req.rid == rid:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return req
        return None

    def admit(self, fits: Optional[Callable[[GenerationRequest], bool]] = None
              ) -> list[tuple[int, GenerationRequest]]:
        """Fill free slots from the queue in priority order; returns the new
        placements. Requests whose deadline elapsed are shed into
        ``pop_shed()`` instead of placed.

        ``fits`` (optional) is an engine-side capacity predicate checked
        against the HIGHEST-priority pending request before it is popped:
        admission stops at the first request that does not fit (it stays
        queued, in order), letting token-mode engines refuse admission when
        the shared cache cursor cannot cover prompt + max_new_tokens."""
        placed = []
        now = self._clock()
        free = [s for s, r in enumerate(self.active) if r is None]
        while free and self._heap:
            req = self._heap[0][2]
            if self._expired(req, now):
                heapq.heappop(self._heap)
                self._shed.append(req)
                continue
            if fits is not None and not fits(req):
                break
            heapq.heappop(self._heap)
            slot = free.pop(0)
            req.admit_t = now
            self.active[slot] = req
            placed.append((slot, req))
        return placed

    def complete(self, slot: int) -> GenerationRequest:
        req = self.active[slot]
        assert req is not None, f"slot {slot} is empty"
        self.active[slot] = None
        self.done.append(req)
        return req

    # --------------------------------------------------------------- drains
    def pop_done(self) -> list[GenerationRequest]:
        """Return-and-clear the completed list (the non-leaking way to
        consume results from a long-lived engine; ``done`` keeps
        accumulating otherwise)."""
        drained, self.done = self.done, []
        return drained

    def pop_shed(self) -> list[GenerationRequest]:
        """Return-and-clear requests shed at admission (deadline expired);
        the engine finalizes these with ``finish_reason='shed'``."""
        drained, self._shed = self._shed, []
        return drained

    # ------------------------------------------------------------- queries
    def peek(self) -> Optional[GenerationRequest]:
        """The next request ``admit`` would consider (highest priority),
        without popping it."""
        return self._heap[0][2] if self._heap else None

    @property
    def queue(self) -> list[GenerationRequest]:
        """Pending requests in admission order (a snapshot — the live
        structure is a heap; supports ``len``/iteration like the old
        deque)."""
        return [req for _, _, req in sorted(self._heap)]

    @property
    def queue_depth(self) -> int:
        return len(self._heap)

    @property
    def has_work(self) -> bool:
        # _shed counts as work: entries shed at submit() time (not just
        # inside admit()) still need the engine's pop_shed() drain to be
        # finalized — otherwise an emptied queue could strand them with no
        # finish_reason and a stream that never resolves
        return (self.queue_depth > 0 or len(self._shed) > 0
                or any(r is not None for r in self.active))

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)

    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.active) if r is not None]


def group_admits(placed: list, key_fn: Callable, max_batch: int
                 ) -> list[tuple[object, list]]:
    """Group one admission round's placements for batched prefill.

    Placements with equal ``key_fn(item)`` (the engine keys on (bucket,
    cached-prefix identity)) batch into ONE prefill forward, chunked to
    ``max_batch`` rows each. Deterministic: groups appear in first-seen
    order, items keep their admission order within a group — so a given
    submit sequence always yields the same batches, and ``max_batch=1``
    degenerates to the serial one-forward-per-request schedule."""
    groups: dict = {}
    order: list = []
    for item in placed:
        key = key_fn(item)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(item)
    out = []
    for key in order:
        members = groups[key]
        for i in range(0, len(members), max_batch):
            out.append((key, members[i:i + max_batch]))
    return out
