"""Prefill-only encoder requests: classify / embed / score (DESIGN.md §14).

MKQ-BERT's deployment target is an *encoder* — the paper's end-to-end claim
is int4 BERT classification, not autoregressive decode. This module is the
request surface for that workload: an :class:`EncodeRequest` resolves to
logits, a pooled embedding, or a scalar score from ONE batched bucketed
forward through the deployed int4/int8 plan — no KV retention, no decode
loop. Requests ride the SAME scheduler machinery as generation traffic
(priority heap, bounded queue, deadline shedding, cancellation, Clock,
ServeMetrics): the engine duck-types on the fields both request classes
share (``rid``/``priority``/``deadline_s``/submit/admit stamps), so encode
and decode requests coexist in one ``engine_step()`` pump.

Tasks (family-dependent — validated at ``submit_encode``):

* ``classify`` — (num_classes,) logits from the CLS pool + classifier head
  (bert classifier artifacts).
* ``embed``    — (d_model,) tanh-pooled CLS embedding (bert).
* ``score``    — one scalar: bert artifacts return the positive-class
  log-probability (relevance scoring); DECODER artifacts return the
  prompt's total log-likelihood ``sum_i log p(t_i | t_<i)`` — which is how
  a decode engine serves encode traffic through the same slot table.

Exactness: encoder attention is bidirectional, so bucket padding is NOT
free the way it is for causal prefill — padded keys are masked per row
(``bert_encode(lengths=...)``), which makes a padded batch row bit-identical
to the unpadded forward. Batch rows are independent, so results never
depend on which other requests share the group (the PR-5 property, now for
encoders).

Like ``api``, this module is a leaf: the engine imports it, never the
reverse.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = ["EncodeRequest", "EncodeResult", "EncodeHandle", "ENCODE_TASKS",
           "ENCODE_FINISH_REASONS"]

#: what an EncodeRequest may ask for (validated again per-family at submit)
ENCODE_TASKS = ("classify", "embed", "score")

#: terminal states: completed / cancelled while queued / deadline-shed
ENCODE_FINISH_REASONS = ("done", "cancelled", "shed")


@dataclasses.dataclass
class EncodeRequest:
    """A prefill-only job: tokens + task + admission policy.

    tokens      (plen,) int32 — the full input; there is no generation side.
    task        'classify' | 'embed' | 'score' (ENCODE_TASKS).
    priority    higher admits first; shares the heap with generation traffic.
    deadline_s  seconds after submit by which the request must be ADMITTED;
                past it the scheduler sheds it (``finish_reason='shed'``,
                result None) — same semantics as GenerationRequest.
    """

    tokens: np.ndarray
    task: str = "classify"
    priority: int = 0
    deadline_s: Optional[float] = None
    result: Optional[np.ndarray] = None
    rid: int = -1                   # assigned by the scheduler on submit
    finish_reason: Optional[str] = None
    # monotonic-clock stamps, filled in by scheduler/engine (repr noise)
    submit_t: Optional[float] = dataclasses.field(default=None, repr=False)
    admit_t: Optional[float] = dataclasses.field(default=None, repr=False)
    finish_t: Optional[float] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        if self.task not in ENCODE_TASKS:
            raise ValueError(f"task must be one of {ENCODE_TASKS}, "
                             f"got {self.task!r}")
        self.tokens = np.asarray(self.tokens, np.int32)

    # the scheduler reads ``prompt`` for nothing, but the engine's length
    # validation and the load generator both key on it — alias the tokens
    @property
    def prompt(self) -> np.ndarray:
        return self.tokens

    # ------------------------------------------------------------- timing
    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.submit_t is None or self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    @property
    def latency_s(self) -> Optional[float]:
        """Submit → result (the encode analogue of TTFT)."""
        if self.submit_t is None or self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    def to_result(self) -> "EncodeResult":
        assert self.finish_reason is not None, \
            f"encode request {self.rid} has not finished"
        return EncodeResult(rid=self.rid, task=self.task, value=self.result,
                            finish_reason=self.finish_reason,
                            latency_s=self.latency_s,
                            queue_wait_s=self.queue_wait_s)


@dataclasses.dataclass(frozen=True)
class EncodeResult:
    """Terminal snapshot of a finished encode request."""

    rid: int
    task: str
    value: Optional[np.ndarray]     # logits (C,) / embedding (d,) / score ();
    finish_reason: str              # None for shed/cancelled
    latency_s: Optional[float]
    queue_wait_s: Optional[float]


class EncodeHandle:
    """Future-style handle to a submitted encode request.

    Mirrors :class:`~repro.serving.api.TokenStream`: the engine is
    single-threaded, so ``result()`` pumps ``engine_step()`` until this
    request resolves. ``on_result(rid, value)`` fires from inside the
    engine's step when the forward completes (None for shed/cancel).
    """

    def __init__(self, engine, request: EncodeRequest,
                 on_result: Optional[Callable[[int, object], None]] = None):
        self._engine = engine
        self.request = request
        self.on_result = on_result
        self.finished = False

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finish_reason(self) -> Optional[str]:
        return self.request.finish_reason

    # ------------------------------------------------- engine-facing hook
    def _finish(self) -> None:
        self.finished = True
        if self.on_result is not None:
            self.on_result(self.request.rid, self.request.result)

    # ---------------------------------------------------------- user side
    def result(self) -> EncodeResult:
        """Pump the engine until this request finishes."""
        while not self.finished:
            if not self._engine.scheduler.has_work:
                raise RuntimeError(
                    f"encode request {self.rid} unfinished but engine is "
                    "drained")
            self._engine.engine_step()
        return self.request.to_result()

    def cancel(self) -> bool:
        return self._engine.cancel(self.rid)
