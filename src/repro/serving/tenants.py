"""Multi-tenant serving: several deployed artifacts, one process, fair-share
admission (DESIGN.md §14).

A :class:`MultiTenantEngine` hosts a registry of named tenants — each a
:class:`~repro.serving.engine.ServingEngine` over its own
:class:`~repro.deploy.DeployedModel` (an int4 W4A4 BERT classifier and an
int4 decoder can share the process) — behind one submit surface and one
``engine_step()`` pump, so the load generator, the CLI and the virtual-clock
harness drive a fleet exactly like a single engine.

Isolation is per tenant; scheduling is shared:

* **bounded queues** — each tenant keeps its own ``max_queue`` (backpressure
  rejects that tenant's submits without touching its neighbours).
* **token-budget quotas** — an optional cap on a tenant's OUTSTANDING tokens
  (prompt + requested output of everything queued or running); a submit past
  it raises :class:`QuotaExceededError` (a ``QueueFullError``, so load
  generators already count it as ``rejected``).
* **deficit round-robin** — each ``engine_step()`` runs ONE tenant's step.
  A tenant's deficit counter gains ``weight * quantum_tokens`` when its turn
  starts and pays the tokens the step actually processed (prefill + decode +
  encode, via ``engine.last_step_tokens``); the turn ends when the deficit
  is spent or the tenant drains. Work is conserved (an idle tenant's turn
  costs nothing) and no tenant starves: a backlogged tenant's turn comes
  around after every other tenant spends at most one quantum — the classic
  DRR O(1) fairness bound, measured per-tenant by the shared
  :class:`~repro.serving.metrics.ServeMetrics` rollups.

Request ids are assigned from ONE shared counter at submit (the per-tenant
``Scheduler.assign_id`` respects pre-assigned ids), so a rid names a request
process-wide — ``cancel(rid)``/``pop_done()`` need no tenant argument.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from .api import GenerationRequest, QueueFullError, TokenStream
from .clock import SYSTEM_CLOCK, Clock
from .encoder import EncodeHandle, EncodeRequest
from .engine import ServingEngine
from .metrics import ServeMetrics

__all__ = ["MultiTenantEngine", "QuotaExceededError", "TenantState"]


class QuotaExceededError(QueueFullError):
    """A tenant's outstanding-token budget is spent; submit again after some
    of its work finishes. Subclasses ``QueueFullError`` so existing
    backpressure handling (load generators, CLI) already treats it as a
    rejection."""


@dataclasses.dataclass
class TenantState:
    """Registry entry: the tenant's engine + its fair-share accounting."""

    name: str
    engine: ServingEngine
    weight: int = 1                       # DRR share multiplier
    token_budget: Optional[int] = None    # cap on outstanding tokens
    deficit: float = 0.0                  # DRR credit (tokens)
    outstanding: dict = dataclasses.field(default_factory=dict)  # rid -> cost

    @property
    def outstanding_tokens(self) -> int:
        return sum(self.outstanding.values())


class _SchedView:
    """The scheduler-shaped facade handles and load generators poll:
    ``TokenStream``/``EncodeHandle`` pump their ``_engine`` while
    ``_engine.scheduler.has_work`` — for a multi-tenant engine that means
    "any tenant has work"."""

    def __init__(self, mt: "MultiTenantEngine"):
        self._mt = mt

    @property
    def has_work(self) -> bool:
        return any(t.engine.scheduler.has_work
                   for t in self._mt.tenants.values())

    @property
    def queue_depth(self) -> int:
        return sum(t.engine.scheduler.queue_depth
                   for t in self._mt.tenants.values())

    @property
    def num_active(self) -> int:
        return sum(t.engine.scheduler.num_active
                   for t in self._mt.tenants.values())


class MultiTenantEngine:
    """Deficit-round-robin multiplexer over named :class:`ServingEngine`\\ s.

    Tenants share the clock and the metrics object (per-tenant rollups land
    under the summary's ``by_label`` key); everything else — model, slots,
    queue bound, quota, weight — is per tenant.
    """

    def __init__(self, *, clock: Clock = SYSTEM_CLOCK,
                 metrics: Optional[ServeMetrics] = None,
                 quantum_tokens: int = 64):
        if quantum_tokens <= 0:
            raise ValueError(f"quantum_tokens must be positive, "
                             f"got {quantum_tokens}")
        self.clock = clock
        self.metrics = (metrics if metrics is not None
                        else ServeMetrics(clock=clock))
        self.quantum_tokens = quantum_tokens
        self.tenants: dict[str, TenantState] = {}
        self._order: list[str] = []       # round-robin visiting order
        self._rr = 0                      # index into _order
        self._next_rid = 0                # ONE rid space across tenants
        self.scheduler = _SchedView(self)
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0

    # ------------------------------------------------------------- registry
    def add_tenant(self, name: str, model, *, slots: int = 4,
                   max_len: int = 512, max_queue: Optional[int] = None,
                   weight: int = 1, token_budget: Optional[int] = None
                   ) -> TenantState:
        """Register ``name`` over ``model`` (a DeployedModel). The tenant's
        engine shares the process clock and metrics; ``weight`` scales its
        DRR share, ``token_budget`` caps its outstanding tokens."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if weight <= 0:
            raise ValueError(f"tenant {name!r}: weight must be positive")
        engine = ServingEngine(model, slots=slots, max_len=max_len,
                               max_queue=max_queue, metrics=self.metrics,
                               clock=self.clock, tenant=name)
        t = TenantState(name=name, engine=engine, weight=weight,
                        token_budget=token_budget)
        self.tenants[name] = t
        self._order.append(name)
        return t

    def _tenant(self, name: str) -> TenantState:
        t = self.tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}; registered: "
                           f"{sorted(self.tenants)}")
        return t

    # --------------------------------------------------------------- submit
    def _charge(self, t: TenantState, req, cost: int) -> None:
        """Quota check + rid assignment, BEFORE the engine sees the request
        (a quota rejection must not consume a queue slot)."""
        if t.token_budget is not None and \
                t.outstanding_tokens + cost > t.token_budget:
            raise QuotaExceededError(
                f"tenant {t.name!r}: outstanding {t.outstanding_tokens} + "
                f"{cost} tokens exceeds budget {t.token_budget}")
        if req.rid < 0:                   # shared rid space (assign_id
            req.rid = self._next_rid      # keeps pre-assigned ids)
            self._next_rid += 1
        t.outstanding[req.rid] = cost

    def submit(self, req: GenerationRequest, *, tenant: str,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> TokenStream:
        t = self._tenant(tenant)
        cost = len(req.prompt) + req.max_new_tokens
        self._charge(t, req, cost)
        try:
            stream = t.engine.submit(req, on_token=on_token)
        except Exception:
            t.outstanding.pop(req.rid, None)
            raise
        stream._engine = self       # iteration pumps the DRR loop, not
        return stream               # just this tenant

    def submit_encode(self, req: EncodeRequest, *, tenant: str,
                      on_result: Optional[Callable[[int, object], None]] = None
                      ) -> EncodeHandle:
        t = self._tenant(tenant)
        self._charge(t, req, len(req.tokens))
        try:
            handle = t.engine.submit_encode(req, on_result=on_result)
        except Exception:
            t.outstanding.pop(req.rid, None)
            raise
        handle._engine = self
        return handle

    # ----------------------------------------------------------------- pump
    def _release_finished(self, t: TenantState) -> None:
        """Return finished requests' tokens to the tenant's quota. The done
        list persists until ``pop_done`` drains it, so releasing is keyed on
        the outstanding map (each rid releases once)."""
        if not t.outstanding:
            return
        for req in t.engine.scheduler.done:
            t.outstanding.pop(req.rid, None)

    def engine_step(self) -> list[tuple[int, int]]:
        """ONE tenant's ``engine_step`` under deficit round-robin; returns
        that step's ``(rid, token)`` events. Idle tenants are skipped at
        zero cost (their deficit resets — credit must not accumulate while
        there is nothing to spend it on)."""
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0
        n = len(self._order)
        for _ in range(n):
            t = self.tenants[self._order[self._rr]]
            if not t.engine.scheduler.has_work:
                t.deficit = 0.0
                self._rr = (self._rr + 1) % n
                continue
            if t.deficit <= 0:
                t.deficit += t.weight * self.quantum_tokens
            events = t.engine.engine_step()
            # a step that only sheds/admits still pays 1 so a turn always
            # terminates
            t.deficit -= max(t.engine.last_step_tokens, 1)
            self.last_step_tokens = t.engine.last_step_tokens
            self.last_step_encode_tokens = t.engine.last_step_encode_tokens
            self._release_finished(t)
            if t.deficit <= 0 or not t.engine.scheduler.has_work:
                self._rr = (self._rr + 1) % n     # turn over
            return events
        return []

    def run_until_drained(self, max_steps: int = 100_000) -> int:
        steps = 0
        while self.scheduler.has_work:
            if steps >= max_steps:
                raise RuntimeError(
                    f"MultiTenantEngine: hit max_steps={max_steps} with "
                    f"{self.scheduler.queue_depth} queued and "
                    f"{self.scheduler.num_active} active")
            self.engine_step()
            steps += 1
        return steps

    # ------------------------------------------------------------ lifecycle
    def cancel(self, rid: int) -> bool:
        for t in self.tenants.values():
            if t.engine.cancel(rid):
                t.outstanding.pop(rid, None)
                return True
        return False

    def pop_done(self) -> list:
        """Drain every tenant's finished requests (quota released), in rid
        order so mixed-tenant consumers see one deterministic stream."""
        out = []
        for t in self.tenants.values():
            self._release_finished(t)
            out.extend(t.engine.pop_done())
        out.sort(key=lambda r: r.rid)
        return out
