"""Injectable clocks for the serving stack (DESIGN.md §12).

Every time-dependent decision in serving — deadline shedding, queue-wait /
TTFT stamps, metrics wall time — reads ONE injected clock instead of calling
``time.monotonic()`` inline. A clock is just a zero-argument callable
returning monotonic seconds, so the default (``time.monotonic`` itself) adds
no wrapper object and no behavior change for existing callers.

:class:`VirtualClock` is the deterministic implementation: time advances only
when the owner (the load generator, or a test) says so, via ``advance``/
``advance_to``. Threading it through ``ServingEngine`` + ``Scheduler`` +
``ServeMetrics`` makes every deadline/TTFT/shedding path a pure function of
the op sequence — simulation tests assert EXACT timings with zero sleeps and
zero wall-clock dependence (``tests/test_loadgen.py``).
"""
from __future__ import annotations

import time
from typing import Callable

__all__ = ["Clock", "SYSTEM_CLOCK", "VirtualClock"]

#: A clock is any zero-argument callable returning monotonic seconds.
Clock = Callable[[], float]

#: The default wall clock (what every serving component used inline before).
SYSTEM_CLOCK: Clock = time.monotonic


class VirtualClock:
    """Deterministic simulated clock: ``clock()`` reads, ``advance`` writes.

    Starts at ``start`` seconds and only ever moves forward — rewinding a
    monotonic clock would silently un-expire deadlines mid-flight, so
    ``advance`` rejects negative steps and ``advance_to`` clamps to now.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds; returns the new now."""
        if dt < 0:
            raise ValueError(f"cannot rewind a monotonic clock (dt={dt})")
        self._now += float(dt)
        return self._now

    def advance_to(self, t: float) -> float:
        """Move time forward to ``t`` (no-op when ``t`` is in the past)."""
        self._now = max(self._now, float(t))
        return self._now

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"VirtualClock(now={self._now:.6f})"
