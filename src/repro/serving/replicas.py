"""Data-parallel engine replicas: N engines behind ONE admission surface
(DESIGN.md §16).

Tensor parallelism (the plan's ``tp`` axis) splits one model's weights and
KV heads across devices; a :class:`ReplicaSet` is the other scale axis —
when tp is exhausted (or a single engine's batch is the bottleneck), N
engines over the SAME :class:`~repro.deploy.DeployedModel` arrays serve
independent request batches concurrently. The two compose: each replica's
engine inherits the model plan, tp mesh included.

The set mirrors the :class:`~repro.serving.tenants.MultiTenantEngine`
surface shape — one ``submit``/``engine_step()`` pump, a scheduler-shaped
facade for handles and load generators, one shared
:class:`~repro.serving.metrics.ServeMetrics` and clock — with two deliberate
differences:

* **dispatch, not fair-share** — replicas are interchangeable (same model,
  same limits), so ``submit`` routes each request to the least-loaded
  member (fewest queued + active; ties to the lowest index — deterministic,
  so virtual-clock runs replay byte-identically).
* **every replica pumps per step** — ``engine_step()`` steps ALL members,
  because replicas are CONCURRENT hardware: under the virtual cost model
  (DESIGN.md §12) one ``engine_step`` charges one ``decode_step_s``, so
  stepping all N members per charge is what makes N replicas N times the
  capacity. (The DRR loop in tenants.py steps one member per pump — that
  models one process time-slicing shared compute, the opposite contract.)

Request ids come from ONE shared counter: every member scheduler is pointed
at replica 0's ``itertools.count`` at construction, so a rid names a request
set-wide — ``cancel(rid)``/``pop_done()`` need no replica argument, and
``n>1`` fanout children (which draw rids from their member's own scheduler)
can never collide across replicas.

Determinism: a request's tokens are a function of (prompt, seed) only —
never of which replica (or slot, or batch) serves it — so a ReplicaSet's
streams are byte-identical to a single engine serving the same requests.
"""
from __future__ import annotations

from typing import Callable, Optional

from .api import GenerationRequest, TokenStream
from .clock import SYSTEM_CLOCK, Clock
from .encoder import EncodeHandle, EncodeRequest
from .engine import ServingEngine
from .metrics import ServeMetrics

__all__ = ["ReplicaSet"]


class _SchedView:
    """Scheduler-shaped facade (the tenants.py idiom): handles pump their
    ``_engine`` while ``_engine.scheduler.has_work`` — for a replica set
    that means "any member has work"."""

    def __init__(self, rs: "ReplicaSet"):
        self._rs = rs

    @property
    def has_work(self) -> bool:
        return any(e.scheduler.has_work for e in self._rs.engines)

    @property
    def queue_depth(self) -> int:
        return sum(e.scheduler.queue_depth for e in self._rs.engines)

    @property
    def num_active(self) -> int:
        return sum(e.scheduler.num_active for e in self._rs.engines)


class ReplicaSet:
    """N :class:`ServingEngine` replicas over one deployed model.

    All members share the model arrays (placement included — nothing is
    copied per replica), the metrics object, the clock and the rid space;
    each owns its slots, queue bound and KV state.
    """

    def __init__(self, model, *, replicas: int = 2, slots: int = 8,
                 max_len: int = 512, max_queue: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None,
                 clock: Clock = SYSTEM_CLOCK,
                 kv_budget_bytes: Optional[int] = None,
                 warmup: bool = False):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.clock = clock
        self.metrics = (metrics if metrics is not None
                        else ServeMetrics(clock=clock))
        self.engines = [
            ServingEngine(model, slots=slots, max_len=max_len,
                          max_queue=max_queue, metrics=self.metrics,
                          clock=clock, kv_budget_bytes=kv_budget_bytes,
                          warmup=warmup)
            for _ in range(replicas)]
        # ONE rid space: every member scheduler draws from replica 0's
        # counter object (see Scheduler._ids) — including the rids member
        # engines assign internally to n>1 fanout children.
        ids = self.engines[0].scheduler._ids
        for e in self.engines[1:]:
            e.scheduler._ids = ids
        self.scheduler = _SchedView(self)
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0

    @property
    def replicas(self) -> int:
        return len(self.engines)

    # --------------------------------------------------------------- submit
    def _least_loaded(self) -> ServingEngine:
        """Fewest (queued + active); ties break to the lowest index, so
        dispatch is a pure function of submit order and member load —
        virtual-clock runs replay byte-identically."""
        return min(self.engines,
                   key=lambda e: e.scheduler.queue_depth
                   + e.scheduler.num_active)

    def submit(self, req: GenerationRequest, *,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> TokenStream:
        out = self._least_loaded().submit(req, on_token=on_token)
        # iteration must pump the whole set (this member's work may depend
        # on nothing, but the handle's drain loop polls scheduler.has_work)
        for stream in (out if isinstance(out, list) else (out,)):
            stream._engine = self
        return out

    def submit_encode(self, req: EncodeRequest, *,
                      on_result: Optional[Callable[[int, object], None]] = None
                      ) -> EncodeHandle:
        handle = self._least_loaded().submit_encode(req, on_result=on_result)
        handle._engine = self
        return handle

    # ----------------------------------------------------------------- pump
    def engine_step(self) -> list[tuple[int, int]]:
        """Pump EVERY replica once (concurrent hardware — see module
        docstring); events concatenate in member order, token counters sum."""
        self.last_step_tokens = 0
        self.last_step_encode_tokens = 0
        events: list[tuple[int, int]] = []
        for e in self.engines:
            events.extend(e.engine_step())
            self.last_step_tokens += e.last_step_tokens
            self.last_step_encode_tokens += e.last_step_encode_tokens
        return events

    def run_until_drained(self, max_steps: int = 10000) -> int:
        steps = 0
        while self.scheduler.has_work:
            if steps >= max_steps:
                raise RuntimeError(
                    f"ReplicaSet: hit max_steps={max_steps} with "
                    f"{self.scheduler.queue_depth} queued and "
                    f"{self.scheduler.num_active} active")
            self.engine_step()
            steps += 1
        return steps

    # ------------------------------------------------------------ lifecycle
    def cancel(self, rid: int) -> bool:
        return any(e.cancel(rid) for e in self.engines)

    def pop_done(self) -> list:
        """Drain every member's finished requests in rid order, so the
        merged stream is deterministic regardless of member interleave."""
        out: list = []
        for e in self.engines:
            out.extend(e.pop_done())
        out.sort(key=lambda r: r.rid)
        return out

    @property
    def done(self) -> list:
        return sorted((r for e in self.engines for r in e.done),
                      key=lambda r: r.rid)
