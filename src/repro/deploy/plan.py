"""ExecutionPlan: the resolved, validated execution recipe (DESIGN.md §9).

The serving stack used to thread a loose pile of flags — ``use_pallas``,
``fuse_epilogue``, ``kv_bits``, ``prefill_mode``, a decode dtype — through
``segments_for`` → ``forward`` → ``ServingEngine``, with every layer
re-validating (or forgetting to validate) the combinations. An
``ExecutionPlan`` is built ONCE:

    plan = ExecutionPlan.build(cfg, policy, backend="pallas", kv_bits=8)

and resolves everything up front: the per-segment ``QuantSpec`` list (kernel
selection included), the prefill mode for the config's family, the KV-cache
precision and the decode dtype. It is the single argument
``repro.models.api.forward`` and ``repro.serving.ServingEngine`` consume, and
the policy half of a saved :class:`repro.deploy.DeployedModel` artifact.

Validation lives here — ``api.decode_state`` and the engine no longer carry
their own copies of the family-compatibility checks.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax.numpy as jnp

if TYPE_CHECKING:   # runtime import is lazy: repro.serving imports deploy
    from ..serving.api import SamplingParams

from ..configs.base import ModelConfig
from ..core.policy import QuantPolicy
from ..models.layers import QuantSpec

__all__ = ["ExecutionPlan", "resolve_segments", "validate_cache_layout",
           "TOKEN_ONLY_FAMILIES", "BACKENDS", "MODES"]

#: Families without a {'k','v','len'} decode cache: no chunked prefill, no
#: slot table, no quantized KV — they keep the fp recurrent/decode state.
TOKEN_ONLY_FAMILIES = ("xlstm", "hybrid", "encdec")

BACKENDS = ("reference", "pallas")

#: Execution modes (DESIGN.md §14): 'decode' is the autoregressive serving
#: loop; 'encoder' is the prefill-only mode — one batched bidirectional
#: forward per request (classify/embed/score), no KV retention.
MODES = ("decode", "encoder")

_DECODE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def validate_cache_layout(cfg: ModelConfig, *, per_slot_len: bool = False,
                          kv_bits: int = 16) -> None:
    """Family-compatibility of the decode-cache layout (single source of
    truth; ``api.decode_state`` defers here)."""
    if kv_bits not in (16, 8, 4):
        raise ValueError(f"kv_bits must be 16, 8 or 4, got {kv_bits}")
    if cfg.family in TOKEN_ONLY_FAMILIES and (per_slot_len or kv_bits != 16):
        raise ValueError(
            "per_slot_len/kv_bits: transformer-family caches only "
            f"({cfg.family} keeps the fp decode state)")


def resolve_segments(cfg: ModelConfig, policy: Optional[QuantPolicy],
                     use_pallas: bool = False, fuse_epilogue: bool = False,
                     act_bits: Optional[int] = None
                     ) -> list[tuple[int, int, QuantSpec]]:
    """Policy → contiguous (start, end, QuantSpec) runs for ``cfg``'s family.

    The resolver behind :meth:`ExecutionPlan.build`; the legacy
    ``api.segments_for`` shim also lands here. ``act_bits`` is the plan-level
    activation-precision override (DESIGN.md §13): None keeps the policy's
    per-layer assignment, 4/8 forces that grid on every quantized layer, 0
    keeps activations in floating point (weight-only quantization — the
    parity-testing fallback).
    """
    from ..models import hybrid, transformer
    if policy is None:
        return [(0, _segment_units(cfg), QuantSpec())]
    if cfg.family in ("xlstm", "hybrid"):
        per = cfg.slstm_every if cfg.family == "xlstm" else cfg.attn_every
        return hybrid.group_segments(policy, cfg.num_layers // per,
                                     use_pallas, act_bits=act_bits)
    if cfg.family == "encdec":
        # segments over decoder layers
        if policy.num_layers != cfg.dec_layers:
            raise ValueError(
                f"encdec policy covers decoder layers ({cfg.dec_layers}), "
                f"got num_layers={policy.num_layers}")
    return transformer.segments_from_policy(policy, use_pallas, fuse_epilogue,
                                            act_bits=act_bits)


def _validate_tp(cfg: ModelConfig, policy, backend: str, act_bits,
                 segments, tp: int) -> None:
    """Structural validation of a tensor-parallel plan (DESIGN.md §16).

    Every rule that would otherwise surface as a GSPMD shape error deep in
    deploy (or as silently wrong sampling) is surfaced here, at build time,
    with the knob that caused it named.
    """
    if backend != "reference":
        raise ValueError(
            f"tp={tp}: the pallas kernels are single-device; shard on "
            "backend='reference' (a mesh-aware kernel would land behind "
            "this same build-time check)")
    if cfg.family in TOKEN_ONLY_FAMILIES:
        raise ValueError(
            f"tp={tp}: no sharding rules for family {cfg.family!r}'s fp "
            "recurrent decode state; transformer-cache families only")
    if policy is None or policy.mode != "int":
        raise ValueError(
            f"tp={tp} shards DEPLOYED integer weights (row-parallel "
            "partial sums stay exact in int32); build from a mode='int' "
            "policy")
    if act_bits == 0:
        raise ValueError(
            f"tp={tp} needs integer accumulation for byte-identical "
            "streams; act_bits=0 contracts in floating point over the "
            "sharded axis")
    for dim_name, dim in (("num_heads", cfg.num_heads),
                          ("num_kv_heads", cfg.num_kv_heads),
                          ("d_ff", cfg.d_ff)):
        if dim % tp:
            raise ValueError(
                f"tp={tp} does not divide {dim_name}={dim}; pick a tp "
                "that divides the attention-head and FFN dims")
    # int4 codes pack 2 values per int8 byte along the CONTRACTING axis
    # (core/packing.py pack_axis=-2), so a row-parallel int4 weight shards
    # its PACKED K/2 rows: K must divide by 2*tp, not just tp.
    if any(sp.w_bits == 4 for _, _, sp in segments):
        for dim_name, dim in (("num_heads*head_dim", cfg.num_heads * cfg.hd),
                              ("d_ff", cfg.d_ff)):
            if dim % (2 * tp):
                raise ValueError(
                    f"tp={tp} with int4 segments: packed codes shard the "
                    f"K/2 nibble-pair rows, so {dim_name}={dim} must "
                    f"divide by 2*tp={2 * tp}")


def _segment_units(cfg: ModelConfig) -> int:
    if cfg.family == "xlstm":
        return cfg.num_layers // cfg.slstm_every
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.dec_layers
    return cfg.num_layers


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything the forward/serving path needs, resolved once.

    Use :meth:`build` — the constructor takes already-resolved fields and
    performs no validation.
    """

    cfg: ModelConfig
    policy: Optional[QuantPolicy]
    backend: str                 # 'reference' | 'pallas'
    kv_bits: int                 # 16 (fp rows) | 8 | 4 (packed, DESIGN.md §8)
    prefill_mode: str            # 'chunked' | 'token' (resolved, never 'auto')
    decode_dtype: str            # 'float32' | 'bfloat16'
    fuse_epilogue: bool
    segments: tuple              # ((start, end, QuantSpec), ...)
    #: resolved serving sampling defaults (DESIGN.md §10): requests that
    #: carry ``sampling=None`` inherit these. Greedy unless built otherwise.
    default_sampling: "Optional[SamplingParams]" = None
    #: shared-prefix KV reuse budget in bytes (DESIGN.md §11); 0 disables.
    #: Artifacts written before this knob existed load with it off.
    prefix_cache: int = 0
    #: max admissions grouped into ONE batch-N prefill forward (DESIGN.md
    #: §11); 1 keeps the serial batch-1 prefill schedule.
    prefill_batch: int = 1
    #: plan-level activation precision override (DESIGN.md §13). None keeps
    #: the policy's per-layer assignment (old artifacts load with this);
    #: 4/8 force that activation grid on every quantized segment; 0 keeps
    #: activations fp (weight-only — the parity-testing fallback).
    act_bits: Optional[int] = None
    #: execution mode (DESIGN.md §14): 'decode' (default; every artifact
    #: written before this knob existed loads as it) or 'encoder' — the
    #: prefill-only mode serving EncodeRequests (classify/embed/score)
    #: through one batched bidirectional forward, no KV retention.
    mode: str = "decode"
    #: KV memory layout (DESIGN.md §15): 'dense' preallocates slots×max_len
    #: rows per slot (the original layout; artifacts written before this
    #: knob existed load as it); 'paged' routes the cache through the
    #: refcounted block pool — block tables, prefix sharing by reference,
    #: copy-on-write forks, one byte budget for admission AND eviction.
    kv_paging: str = "dense"
    #: tensor-parallel degree (DESIGN.md §16): how many devices the packed
    #: int4/int8 weight codes, scales, biases and KV heads are sharded
    #: across on a 1-axis ("model",) mesh. 1 (default; every artifact
    #: written before this knob existed loads as it) keeps the
    #: single-device layout. Reference backend only — integer accumulation
    #: makes the row-parallel partial sums exact, so streams are
    #: byte-identical to tp=1.
    tp: int = 1

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, cfg: ModelConfig, policy: Optional[QuantPolicy] = None, *,
              backend: str = "reference", kv_bits: Optional[int] = None,
              prefill_mode: str = "auto", decode_dtype: str = "float32",
              fuse_epilogue: Optional[bool] = None,
              sampling=None, prefix_cache: int = 0,
              prefill_batch: int = 1,
              act_bits: Optional[int] = None,
              mode: str = "decode",
              kv_paging: str = "dense",
              tp: int = 1) -> "ExecutionPlan":
        """Resolve + validate a plan.

        backend       'pallas' routes int matmuls (and quantized-KV decode
                      attention) through the Pallas kernels; 'reference' is
                      the jnp int path.
        kv_bits       None follows ``cfg.kv_bits``.
        prefill_mode  'auto' resolves per family: 'chunked' for transformer
                      KV-cache families, 'token' (seed semantics) otherwise.
        decode_dtype  the ONE fp dtype of the serving decode state — engine,
                      slot cache and prefill all inherit it from the plan.
        fuse_epilogue None fuses whenever the backend is 'pallas' (fusing is
                      statically gated to deployed int4 + gelu/relu FFNs in
                      ``models.transformer.ffn_apply``, so this is safe for
                      every segment mix); pass an explicit bool to override.
        sampling      serving sampling defaults (``SamplingParams``, a dict
                      of its kwargs, or None for greedy) — requests without
                      explicit sampling inherit these; round-trips through
                      the artifact meta like every other build knob.
        prefix_cache  byte budget for shared-prefix KV reuse (DESIGN.md
                      §11); 0 (the default) disables it. Needs the chunked
                      slot-cache prefill path.
        prefill_batch max same-bucket admissions grouped into one batch-N
                      prefill forward (compiled per (bucket, n) with n
                      padded to a power of two); 1 keeps serial prefills.
        act_bits      activation precision override (DESIGN.md §13): None
                      follows the policy per layer; 4/8 retarget every
                      quantized segment onto that grid (the artifact's
                      calibrated scales are rescaled by the qmax ratio);
                      0 runs fp activations against dequantized weights —
                      reference backend only, the parity baseline.
        mode          'decode' (default) or 'encoder' (DESIGN.md §14): the
                      prefill-only execution mode — requests resolve to
                      logits / pooled embeddings / scores from ONE batched
                      forward, no KV retention, so kv_bits must stay 16 and
                      the prefix cache must be off. Needs a family with a
                      bidirectional encode path (bert).
        kv_paging     'dense' (default; old artifacts load as it) keeps the
                      preallocated slots×max_len layout; 'paged' allocates
                      KV in PREFIX_BLOCK-token blocks from one refcounted,
                      byte-budgeted pool (DESIGN.md §15) — prefix hits
                      attach blocks by reference, n>1 samples fork
                      copy-on-write, admission is gated on worst-case block
                      need. Needs the chunked slot-cache prefill path and
                      mode='decode'. Token streams are bit-identical to
                      'dense'.
        tp            tensor-parallel degree (DESIGN.md §16): shards packed
                      weight codes/scales column- or row-parallel and KV
                      heads across a ("model",) mesh of ``tp`` devices.
                      Validated structurally here (divisibility, backend,
                      family); the mesh itself is built lazily at placement
                      (:meth:`make_mesh`), so a sharded plan/artifact can be
                      inspected on any host.
        """
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if decode_dtype not in _DECODE_DTYPES:
            raise ValueError(f"decode_dtype must be one of "
                             f"{sorted(_DECODE_DTYPES)}, got {decode_dtype!r}")
        kv_bits = cfg.kv_bits if kv_bits is None else kv_bits

        if prefill_mode == "auto":
            prefill_mode = ("token" if cfg.family in TOKEN_ONLY_FAMILIES
                            else "chunked")
        if prefill_mode not in ("chunked", "token"):
            raise ValueError(f"prefill_mode must be 'auto', 'chunked' or "
                             f"'token', got {prefill_mode!r}")
        if prefill_mode == "chunked" and cfg.family in TOKEN_ONLY_FAMILIES:
            raise ValueError(
                f"{cfg.family}: no KV slot cache; use prefill_mode='token'")
        validate_cache_layout(cfg, kv_bits=kv_bits)
        if prefill_mode == "token" and kv_bits != 16:
            raise ValueError(
                "kv_bits < 16 needs the chunked slot cache; token-mode "
                "prefill keeps the fp decode state")
        prefix_cache = int(prefix_cache)
        prefill_batch = int(prefill_batch)
        if prefix_cache < 0:
            raise ValueError(f"prefix_cache must be >= 0 (bytes; 0 "
                             f"disables), got {prefix_cache}")
        if prefill_batch < 1:
            raise ValueError(f"prefill_batch must be >= 1, "
                             f"got {prefill_batch}")
        if prefix_cache and prefill_mode != "chunked":
            raise ValueError(
                "prefix_cache needs the chunked slot-cache prefill path; "
                f"prefill_mode={prefill_mode!r} has no KV rows to reuse")
        if prefix_cache and cfg.learned_pos:
            raise ValueError(
                "prefix_cache: block-chunked prefill derives positions from "
                "the KV cursor (RoPE); learned-pos embeddings index from 0 "
                "and would disagree between chunked and whole-prompt runs")

        if act_bits is not None:
            act_bits = int(act_bits)
            if act_bits not in (0, 4, 8):
                raise ValueError(f"act_bits must be None, 0, 4 or 8, "
                                 f"got {act_bits}")
            if policy is None:
                raise ValueError(
                    "act_bits: nothing to retarget without a policy "
                    "(fp plans have no quantized segments)")
            if act_bits == 0 and backend != "reference":
                raise ValueError(
                    "act_bits=0 (fp activations) is the reference-backend "
                    "parity path; the pallas int kernels consume activation "
                    "codes")

        if mode == "encoder":
            # prefill-only: one bidirectional forward, results read straight
            # from the logits/hidden states — nothing is ever cached, so a
            # quantized (or any) KV layout and prefix reuse are meaningless
            # rather than merely unused. Surface the contradiction at build.
            if cfg.family != "bert":
                raise ValueError(
                    f"mode='encoder' needs a bidirectional encode path "
                    f"(family 'bert'), got family {cfg.family!r}")
            if kv_bits != 16:
                raise ValueError(
                    "mode='encoder' retains no KV cache; kv_bits must stay "
                    f"16 (got {kv_bits})")
            if prefix_cache:
                raise ValueError(
                    "mode='encoder' computes every request in one forward; "
                    "prefix_cache has no KV rows to reuse")
            if prefill_mode == "token":
                raise ValueError(
                    "mode='encoder' runs the batched bucketed forward; "
                    "prefill_mode='token' (seed semantics) does not apply")

        if kv_paging not in ("dense", "paged"):
            raise ValueError(f"kv_paging must be 'dense' or 'paged', "
                             f"got {kv_paging!r}")
        if kv_paging == "paged":
            if mode != "decode":
                raise ValueError(
                    "kv_paging='paged' pages the decode KV cache; "
                    f"mode={mode!r} retains none")
            if prefill_mode != "chunked":
                raise ValueError(
                    "kv_paging='paged' needs the chunked slot-cache prefill "
                    f"path; prefill_mode={prefill_mode!r} has no KV rows "
                    "to page")

        use_pallas = backend == "pallas"
        if fuse_epilogue is None:
            fuse_epilogue = use_pallas
        # lazy import: repro.serving imports repro.deploy at module load, so
        # the reverse edge must wait until build() runs (never at import)
        from ..serving.api import SamplingParams
        sampling = SamplingParams.resolve(sampling)
        segments = resolve_segments(cfg, policy, use_pallas, fuse_epilogue,
                                    act_bits=act_bits)

        tp = int(tp)
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        if tp > 1:
            _validate_tp(cfg, policy, backend, act_bits, segments, tp)
        return cls(cfg=cfg, policy=policy, backend=backend, kv_bits=kv_bits,
                   prefill_mode=prefill_mode, decode_dtype=decode_dtype,
                   fuse_epilogue=fuse_epilogue, segments=tuple(segments),
                   default_sampling=sampling, prefix_cache=prefix_cache,
                   prefill_batch=prefill_batch, act_bits=act_bits, mode=mode,
                   kv_paging=kv_paging, tp=tp)

    # ------------------------------------------------------------ queries
    @property
    def use_pallas(self) -> bool:
        return self.backend == "pallas"

    @property
    def jnp_dtype(self):
        return _DECODE_DTYPES[self.decode_dtype]

    @property
    def deployed(self) -> bool:
        """True when the segments carry deployed-int QuantSpecs."""
        return self.policy is not None and self.policy.mode == "int"

    def make_mesh(self):
        """The ("model",) mesh for this plan's tp degree, or None at tp=1.

        Lazy on purpose: device availability is checked at PLACEMENT time,
        not at build — a tp=4 artifact's plan must be constructible (for
        inspection, or to rebuild at a different tp) on a 1-device host.
        """
        if self.tp == 1:
            return None
        from ..launch.mesh import make_tp_mesh
        return make_tp_mesh(self.tp)

    def decode_state(self, batch: int, max_len: int, *,
                     as_specs: bool = False, per_slot_len: bool = False,
                     kv_bits: Optional[int] = None):
        """Allocate (or spec) the decode state with the plan's dtype/kv_bits.

        ``kv_bits`` override exists for the engine's fp batch-1 prefill cache
        (prefill always runs at full precision; quantization happens on slot
        insert — DESIGN.md §8).
        """
        from ..models import api
        return api.decode_state(
            self.cfg, batch, max_len, dtype=self.jnp_dtype,
            as_specs=as_specs, per_slot_len=per_slot_len,
            kv_bits=self.kv_bits if kv_bits is None else kv_bits)

    def build_kwargs(self) -> dict:
        """The exact ``build`` inputs needed to reconstruct this plan (the
        artifact meta stores these — DESIGN.md §9)."""
        return {"backend": self.backend, "kv_bits": self.kv_bits,
                "prefill_mode": self.prefill_mode,
                "decode_dtype": self.decode_dtype,
                "fuse_epilogue": self.fuse_epilogue,
                "sampling": (None if self.default_sampling is None
                             else dataclasses.asdict(self.default_sampling)),
                "prefix_cache": self.prefix_cache,
                "prefill_batch": self.prefill_batch,
                "act_bits": self.act_bits,
                "mode": self.mode,
                "kv_paging": self.kv_paging,
                "tp": self.tp}

    def describe(self) -> str:
        segs = ", ".join(f"[{s}:{e}) w{sp.w_bits or 'fp'}/a{sp.a_bits or 'fp'}"
                         for s, e, sp in self.segments)
        mode = "" if self.mode == "decode" else f"mode={self.mode}, "
        paging = "" if self.kv_paging == "dense" else "kv_paging=paged, "
        paging += "" if self.tp == 1 else f"tp={self.tp}, "
        return (f"ExecutionPlan({self.cfg.name}, {mode}{paging}"
                f"backend={self.backend}, "
                f"kv_bits={self.kv_bits}, prefill={self.prefill_mode}, "
                f"dtype={self.decode_dtype}, segments=({segs}))")


def plan_to_meta(plan: ExecutionPlan) -> dict:
    """JSON-serializable description from which ``plan_from_meta`` rebuilds
    an identical plan (segments re-resolved, not stored)."""
    return {
        "cfg": dataclasses.asdict(plan.cfg),
        "policy": (None if plan.policy is None
                   else dataclasses.asdict(plan.policy)),
        "build": plan.build_kwargs(),
    }


def plan_from_meta(meta: dict) -> ExecutionPlan:
    cfg = ModelConfig.from_dict(meta["cfg"])
    policy = (None if meta["policy"] is None
              else QuantPolicy.from_dict(meta["policy"]))
    return ExecutionPlan.build(cfg, policy, **meta["build"])
