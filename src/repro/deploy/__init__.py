"""Deployment subsystem (DESIGN.md §9): plans + artifacts.

* ``ExecutionPlan`` — the resolved, validated execution recipe (segments /
  kernel selection / KV precision / prefill mode / decode dtype), built once
  and consumed by ``models.api.forward`` and ``serving.ServingEngine``.
* ``DeployedModel`` — the serving artifact: packed int4/int8 weights + scales
  bound to their plan, with atomic ``save``/``load`` so serve runs never
  touch fp weights or recalibrate.
"""
from .artifact import DeployedModel, deploy, retarget_act_bits
from .plan import MODES, ExecutionPlan

__all__ = ["DeployedModel", "ExecutionPlan", "MODES", "deploy",
           "retarget_act_bits"]
