"""DeployedModel: the self-contained serving artifact (DESIGN.md §9).

MKQ-BERT's headline result is *deployed* int4 inference — so deployment is an
artifact, not a script that re-initializes and re-calibrates on every serve
run. ``deploy(params, plan)`` packs the int4/int8 weight codes + scales ONCE;
``DeployedModel.save/load`` round-trip the packed tree and the plan through
``checkpoint/manager.py``'s atomic artifact writer, so

    python -m repro.launch.serve --artifact <dir>

serves with no fp weights in memory and no recalibration, byte-identical to
serving the in-memory model.

Layout:  <dir>/ARTIFACT.json   (format+version, cfg, policy, plan build args)
         <dir>/arrays.npz      (flattened deployed-int leaves; '/'-joined
                                tree paths as keys, list indices numeric)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..checkpoint import manager as ckpt
from ..core import qat
from .plan import ExecutionPlan, plan_from_meta, plan_to_meta

__all__ = ["DeployedModel", "deploy", "ARTIFACT_FORMAT", "ARTIFACT_VERSION"]

ARTIFACT_FORMAT = "mkq-deployed-model"
ARTIFACT_VERSION = 1


def deploy(params, plan: ExecutionPlan, calib_batches: Optional[list] = None,
           *, recalibrate: bool = True) -> "DeployedModel":
    """fp params → packed int artifact under ``plan``.

    params         fp parameter tree (QAT-trained or freshly calibrated).
    calib_batches  optional list of ``{'tokens': ...}`` batches: runs
                   activation-scale calibration (percentile-of-|input|,
                   paper §3.1) through an fp forward before packing.
    recalibrate    recompute weight scales abs-max/qmax (paper §3.1). Pass
                   False for QAT params whose ``s_w`` were LEARNED — LSQ
                   scales must survive into deployment for train==deploy
                   parity (DESIGN.md §6).
    """
    if not plan.deployed:
        raise ValueError(
            "deploy() needs a plan built from a mode='int' QuantPolicy; "
            f"got policy={plan.policy!r}")
    cfg = plan.cfg
    if recalibrate:
        params = qat.calibrate_weight_scales(
            params, qat.default_bits_fn(cfg, plan.policy))
    if calib_batches:
        import jax.numpy as jnp

        from ..models import api
        fp_plan = ExecutionPlan.build(cfg, None, backend="reference",
                                      kv_bits=16,
                                      prefill_mode=plan.prefill_mode,
                                      decode_dtype=plan.decode_dtype)
        fwd = lambda p, b: api.forward(p, fp_plan,
                                       tokens=jnp.asarray(b["tokens"]))[0]
        params = qat.calibrate_act_scales(params, cfg, plan.policy, fwd,
                                          calib_batches)
    params_int = qat.deploy_params(params, cfg, plan.segments)
    return DeployedModel(plan=plan, params=params_int)


@dataclasses.dataclass
class DeployedModel:
    """Packed int4/int8 weights + scales bound to their ExecutionPlan."""

    plan: ExecutionPlan
    params: dict          # deployed-int tree (per-segment layer stacks)

    # ------------------------------------------------------------- persist
    def save(self, path: str) -> str:
        meta = {"format": ARTIFACT_FORMAT, "version": ARTIFACT_VERSION,
                **plan_to_meta(self.plan)}
        return ckpt.save_artifact(path, self.params, meta)

    @classmethod
    def load(cls, path: str) -> "DeployedModel":
        params, meta = ckpt.load_artifact(path)
        if meta.get("format") != ARTIFACT_FORMAT:
            raise ValueError(f"{path}: not a {ARTIFACT_FORMAT} artifact "
                             f"(format={meta.get('format')!r})")
        if meta.get("version", 0) > ARTIFACT_VERSION:
            raise ValueError(
                f"{path}: artifact version {meta['version']} is newer than "
                f"this build understands ({ARTIFACT_VERSION})")
        return cls(plan=plan_from_meta(meta), params=params)

    # ------------------------------------------------------------- serve
    def engine(self, *, slots: int = 8, max_len: int = 512, metrics=None):
        """A ServingEngine over this artifact (lazy import: keeps the
        artifact layer usable without pulling the serving stack)."""
        from ..serving.engine import ServingEngine
        return ServingEngine(self, slots=slots, max_len=max_len,
                             metrics=metrics)
