"""DeployedModel: the self-contained serving artifact (DESIGN.md §9).

MKQ-BERT's headline result is *deployed* int4 inference — so deployment is an
artifact, not a script that re-initializes and re-calibrates on every serve
run. ``deploy(params, plan)`` packs the int4/int8 weight codes + scales ONCE;
``DeployedModel.save/load`` round-trip the packed tree and the plan through
``checkpoint/manager.py``'s atomic artifact writer, so

    python -m repro.launch.serve --artifact <dir>

serves with no fp weights in memory and no recalibration, byte-identical to
serving the in-memory model.

Layout:  <dir>/ARTIFACT.json   (format+version, cfg, policy, plan build args)
         <dir>/arrays.npz      (flattened deployed-int leaves; '/'-joined
                                tree paths as keys, list indices numeric)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..checkpoint import manager as ckpt
from ..core import qat
from .plan import ExecutionPlan, plan_from_meta, plan_to_meta, resolve_segments

__all__ = ["DeployedModel", "deploy", "retarget_act_bits",
           "ARTIFACT_FORMAT", "ARTIFACT_VERSION"]

ARTIFACT_FORMAT = "mkq-deployed-model"
ARTIFACT_VERSION = 1


def deploy(params, plan: ExecutionPlan, calib_batches: Optional[list] = None,
           *, recalibrate: bool = True) -> "DeployedModel":
    """fp params → packed int artifact under ``plan``.

    params         fp parameter tree (QAT-trained or freshly calibrated).
    calib_batches  optional list of ``{'tokens': ...}`` batches: runs
                   activation-scale calibration (percentile-of-|input|,
                   paper §3.1) through an fp forward before packing.
    recalibrate    recompute weight scales abs-max/qmax (paper §3.1). Pass
                   False for QAT params whose ``s_w`` were LEARNED — LSQ
                   scales must survive into deployment for train==deploy
                   parity (DESIGN.md §6).
    """
    if not plan.deployed:
        raise ValueError(
            "deploy() needs a plan built from a mode='int' QuantPolicy; "
            f"got policy={plan.policy!r}")
    cfg = plan.cfg
    if recalibrate:
        params = qat.calibrate_weight_scales(
            params, qat.default_bits_fn(cfg, plan.policy))
    if calib_batches:
        import jax.numpy as jnp

        from ..models import api
        fp_plan = ExecutionPlan.build(cfg, None, backend="reference",
                                      kv_bits=16,
                                      prefill_mode=plan.prefill_mode,
                                      decode_dtype=plan.decode_dtype)
        fwd = lambda p, b: api.forward(p, fp_plan,
                                       tokens=jnp.asarray(b["tokens"]))[0]
        params = qat.calibrate_act_scales(params, cfg, plan.policy, fwd,
                                          calib_batches)
    params_int = qat.deploy_params(params, cfg, plan.segments)
    if plan.act_bits is not None:
        # calibration learned s_a on the POLICY grid; the plan override
        # retargets the stored scales onto its grid (DESIGN.md §13)
        params_int = _rescale_act_scales(
            params_int, cfg, _act_scale_factors(plan, None, plan.act_bits))
    return DeployedModel(plan=plan, params=_place(params_int, plan))


def _place(params, plan: ExecutionPlan):
    """Place packed params on the plan's tp mesh (DESIGN.md §16); a tp=1
    plan keeps the host/default-device tree untouched. Called by both
    ``deploy()`` and ``DeployedModel.load`` — artifacts store full logical
    arrays (``checkpoint/manager.py`` gathers on save), so resharding to a
    different tp is pure placement, no format change."""
    mesh = plan.make_mesh()
    if mesh is None:
        return params
    from ..distributed.sharding import place_serving, serving_param_specs
    return place_serving(params, mesh, serving_param_specs(params))


# ------------------------------------------------------ act-grid retargeting

def _act_scale_factors(plan: ExecutionPlan, old_act_bits, new_act_bits
                       ) -> list[float]:
    """Per-segment multipliers moving stored ``s_a`` leaves between
    activation grids (DESIGN.md §13).

    The MKQ grid pins the real-valued clip point ``s * qmax(bits)``, so
    retargeting bits is a pure rescale: ``s_new = s_old * qmax(old)/qmax(new)``
    — no re-calibration. Scales of fp-activation segments (a_bits 0) stay on
    the policy grid, which keeps retargeting composable in any order.
    A plan-level override is applied per quantized layer, so it can never
    move segment boundaries (asserted here, not regrouped).
    """
    from ..core.quantizer import qrange
    cfg, policy = plan.cfg, plan.policy
    segs = lambda ab: resolve_segments(cfg, policy, plan.use_pallas,
                                       plan.fuse_epilogue, act_bits=ab)
    old, new, pol = segs(old_act_bits), segs(new_act_bits), segs(None)
    factors = []
    for (so, eo, spo), (sn, en, spn), (_, _, spp) in zip(old, new, pol):
        if (so, eo) != (sn, en):
            raise AssertionError(
                "act_bits override moved a segment boundary "
                f"([{so}:{eo}) vs [{sn}:{en})) — a_bits must stay a pure "
                "function of w_bits")
        go = spo.a_bits or spp.a_bits   # grid the scales are stored on
        gn = spn.a_bits or spp.a_bits   # grid they must land on
        factors.append(1.0 if go == gn
                       else float(qrange(go)[1]) / float(qrange(gn)[1]))
    return factors


def _rescale_act_scales(params_int, cfg, factors: list[float]):
    """Multiply every linear's ``s_a`` by its segment's factor, mirroring
    ``qat.deploy_params``'s per-family layout."""
    import jax.numpy as jnp

    def scale_tree(tree, f):
        if f == 1.0:
            return tree
        def walk(node):
            if isinstance(node, dict):
                if "s_a" in node and ("wq" in node or "w" in node):
                    new = dict(node)
                    new["s_a"] = (jnp.asarray(node["s_a"], jnp.float32)
                                  * f).astype(node["s_a"].dtype)
                    return new
                return {k: walk(v) for k, v in node.items()}
            return node
        return walk(tree)

    out = dict(params_int)
    if cfg.family in ("xlstm", "hybrid"):
        key = "mlstm" if cfg.family == "xlstm" else "mamba"
        out[key] = [scale_tree(t, f)
                    for t, f in zip(params_int[key], factors)]
        if cfg.family == "xlstm":
            out["slstm"] = [scale_tree(t, f)
                            for t, f in zip(params_int["slstm"], factors)]
        else:
            out["shared"] = scale_tree(params_int["shared"], factors[-1])
        return out
    if cfg.family == "encdec":
        out["enc"] = scale_tree(params_int["enc"], factors[0])
        out["dec"] = [scale_tree(t, f)
                      for t, f in zip(params_int["dec"], factors)]
        return out
    out["layers"] = [scale_tree(t, f)
                     for t, f in zip(params_int["layers"], factors)]
    return out


def retarget_act_bits(model: "DeployedModel", act_bits,
                      *, backend: Optional[str] = None) -> "DeployedModel":
    """A new DeployedModel serving the same packed weights at a different
    activation precision (DESIGN.md §13).

    ``act_bits`` as in :meth:`ExecutionPlan.build`: 4/8 pick that grid for
    every quantized segment, 0 runs fp activations (reference backend — the
    backend is switched automatically unless overridden), None returns to
    the policy's per-layer assignment. Stored ``s_a`` scales are rescaled by
    the qmax ratio; weights, codes and every other plan knob are untouched.
    """
    plan = model.plan
    if not plan.deployed:
        raise ValueError("retarget_act_bits needs a deployed (mode='int') "
                         "artifact")
    kw = plan.build_kwargs()
    kw["act_bits"] = act_bits
    if backend is not None:
        kw["backend"] = backend
    elif act_bits == 0 and kw["backend"] != "reference":
        kw["backend"] = "reference"   # fp activations: parity path
    if kw["backend"] == "reference":
        kw["fuse_epilogue"] = False   # fusing is a pallas-only notion
    new_plan = ExecutionPlan.build(plan.cfg, plan.policy, **kw)
    params = _rescale_act_scales(
        model.params, plan.cfg,
        _act_scale_factors(plan, plan.act_bits, act_bits))
    return DeployedModel(plan=new_plan, params=params)


@dataclasses.dataclass
class DeployedModel:
    """Packed int4/int8 weights + scales bound to their ExecutionPlan."""

    plan: ExecutionPlan
    params: dict          # deployed-int tree (per-segment layer stacks)

    # ------------------------------------------------------------- persist
    def save(self, path: str) -> str:
        meta = {"format": ARTIFACT_FORMAT, "version": ARTIFACT_VERSION,
                **plan_to_meta(self.plan)}
        return ckpt.save_artifact(path, self.params, meta)

    @classmethod
    def load(cls, path: str, *, tp: Optional[int] = None) -> "DeployedModel":
        """Load (and place) a saved artifact.

        ``tp`` overrides the RECORDED tensor-parallel layout: the plan is
        rebuilt at the new degree (re-validated — divisibility errors
        surface here, not in GSPMD) and the stored full logical arrays are
        placed under the new mesh, so a tp=2 artifact serves at tp=1 or
        tp=4 without a rewrite. None keeps the recorded layout.
        """
        params, meta = ckpt.load_artifact(path)
        if meta.get("format") != ARTIFACT_FORMAT:
            raise ValueError(f"{path}: not a {ARTIFACT_FORMAT} artifact "
                             f"(format={meta.get('format')!r})")
        if meta.get("version", 0) > ARTIFACT_VERSION:
            raise ValueError(
                f"{path}: artifact version {meta['version']} is newer than "
                f"this build understands ({ARTIFACT_VERSION})")
        if tp is not None:
            meta = dict(meta)
            meta["build"] = {**meta["build"], "tp": int(tp)}
        plan = plan_from_meta(meta)
        return cls(plan=plan, params=_place(params, plan))

    # ------------------------------------------------------------- serve
    def engine(self, *, slots: int = 8, max_len: int = 512, metrics=None,
               warmup: bool = False):
        """A ServingEngine over this artifact (lazy import: keeps the
        artifact layer usable without pulling the serving stack)."""
        from ..serving.engine import ServingEngine
        return ServingEngine(self, slots=slots, max_len=max_len,
                             metrics=metrics, warmup=warmup)
