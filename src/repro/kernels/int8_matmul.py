"""Pallas TPU kernel: int8 x int8 -> int32 matmul with fused dequant epilogue.

The Q8BERT-style baseline layer (paper Table 2 'int8' column), TPU-native:
int8 operands feed the MXU (int8xint8->int32), accumulation lives in a VMEM
scratch, and the per-output-channel dequant (s_a * s_w[n]) is fused into the
epilogue on the last K step — the accumulator never round-trips HBM.

Grid: (M/bm, N/bn, K/bk), K innermost so the (bm, bn) scratch accumulates
across K steps. Default blocks are MXU-aligned (128, 128) tiles with a
512-deep K slab: VMEM = bm*bk + bk*bn (int8) + bm*bn*4 (scratch) = 192 KiB,
well under the ~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, sa_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = sa_ref[0, 0] * sw_ref[...]        # () * (1, bn) f32
        out_ref[...] = (acc_ref[...].astype(jnp.float32) * scale
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def int8_matmul_pallas(x8: jax.Array, w8: jax.Array, s_a: jax.Array,
                       s_w: jax.Array, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """x8: (M, K) int8, w8: (K, N) int8, s_a: () f32, s_w: (1, N) f32."""
    M, K = x8.shape
    K2, N = w8.shape
    assert K == K2, (x8.shape, w8.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x8, w8, s_a.reshape(1, 1), s_w)
