"""jit'd public wrappers for the Pallas kernels (padding, dtype plumbing).

On non-TPU backends the wrappers run the kernels in interpret mode (kernel
body executed in Python on CPU) so the SAME code path is testable offline;
on TPU they compile to Mosaic. ``qlinear`` dispatches here when
``QuantSpec.use_pallas`` is set.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .act_quant import act_quant_pallas
from .decode_attention import decode_attention_pallas
from .int4_matmul import int4_matmul_fused_pallas, int4_matmul_pallas
from .int8_matmul import int8_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    r = x.shape[axis] % m
    if r == 0:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad), m - r


def act_quant(x: jax.Array, s: jax.Array, bits: int = 8) -> jax.Array:
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x2, pm = _pad_to(x2, 8, 0)
    out = act_quant_pallas(x2, s, bits=bits, bm=min(256, x2.shape[0]),
                           interpret=not _on_tpu())
    if pm:
        out = out[:x2.shape[0] - pm]
    return out.reshape(*lead, x.shape[-1])


def int8_matmul(x: jax.Array, w8: jax.Array, s_a: jax.Array, s_w: jax.Array,
                a_bits: int = 8) -> jax.Array:
    """x: (M, K) float -> quantize -> int8 GEMM -> dequant. w8: (K, N) int8."""
    x8 = act_quant(x, s_a, bits=a_bits)
    M, K = x8.shape
    N = w8.shape[1]
    bm = _pick(M, 128)
    bn = _pick(N, 128)
    bk = _pick(K, 512)
    return int8_matmul_pallas(x8, w8, s_a, s_w.reshape(1, N), bm=bm, bn=bn,
                              bk=bk, out_dtype=x.dtype,
                              interpret=not _on_tpu())


def int4_matmul(x: jax.Array, wp: jax.Array, s_a: jax.Array, s_w: jax.Array,
                a_bits: int = 8, bias: jax.Array | None = None,
                act: str | None = None) -> jax.Array:
    """x: (M, K) float; wp: (K/2, N) packed nibbles.

    ``act`` selects the fused decode path: dequant + bias + activation run in
    the kernel epilogue (one HBM write of the (M, N) result instead of three).
    With ``act`` set, ``bias`` (or zeros) is folded in as well.
    """
    x8 = act_quant(x, s_a, bits=a_bits)
    M, K = x8.shape
    if wp.shape[0] * 2 != K:  # packing padded K to even; pad x to match
        x8 = jnp.pad(x8, ((0, 0), (0, wp.shape[0] * 2 - K)))
        K = wp.shape[0] * 2
    N = wp.shape[1]
    bm = _pick(M, 128)
    bn = _pick(N, 128)
    bk = _pick(K, 512, even=True)
    if act is not None:
        b = (jnp.zeros((1, N), jnp.float32) if bias is None
             else bias.reshape(1, N).astype(jnp.float32))
        return int4_matmul_fused_pallas(
            x8, wp, s_a, s_w.reshape(1, N), b, act=act, bm=bm, bn=bn, bk=bk,
            out_dtype=x.dtype, interpret=not _on_tpu())
    return int4_matmul_pallas(x8, wp, s_a, s_w.reshape(1, N), bm=bm, bn=bn,
                              bk=bk, out_dtype=x.dtype,
                              interpret=not _on_tpu())


def decode_attention(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                     k_scale: jax.Array, v_scale: jax.Array,
                     k_new: jax.Array, v_new: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """Decode attention over a quantized KV cache (DESIGN.md §8).

    q: (B, H, dh) float — ONE new token per slot; k_q/v_q: (B, S, Hkv, dhp)
    int8 codes or packed int4 nibbles; k_scale/v_scale: (B, S, Hkv) per-row
    scales; k_new/v_new: (B, Hkv, dh) the current token's fp K/V; lengths:
    per-slot cursors — scalar or (B,). Returns (B, H, dh).
    """
    B, S = q.shape[0], k_q.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (B,))
    bs = _pick(S, 128)
    return decode_attention_pallas(q, k_q, v_q, k_scale, v_scale,
                                   k_new, v_new, lens, bs=bs,
                                   interpret=not _on_tpu())


def _pick(dim: int, target: int, even: bool = False) -> int:
    """Largest divisor of ``dim`` <= target (even if requested)."""
    b = min(dim, target)
    while b > 1:
        if dim % b == 0 and (not even or b % 2 == 0):
            return b
        b -= 1
    return 1
