"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT4_BIAS = 7


def int8_matmul_ref(x8, w8, s_a, s_w, out_dtype=jnp.float32):
    acc = jax.lax.dot_general(x8, w8, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * (s_a * s_w)).astype(out_dtype)


def unpack_int4_ref(wp):
    lo = (wp & 0xF).astype(jnp.int8) - INT4_BIAS
    hi = (wp >> 4).astype(jnp.int8) - INT4_BIAS
    kk, n = wp.shape
    return jnp.stack([lo, hi], axis=1).reshape(kk * 2, n)


def int4_matmul_ref(x8, wp, s_a, s_w, out_dtype=jnp.float32):
    return int8_matmul_ref(x8, unpack_int4_ref(wp), s_a, s_w, out_dtype)


def act_quant_ref(x, s, bits=8):
    from ..core.quantizer import qrange
    qmin, qmax = qrange(bits)
    z = jnp.clip(jnp.round(x.astype(jnp.float32) / s), qmin, qmax)
    return z.astype(jnp.int8)
