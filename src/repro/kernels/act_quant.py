"""Pallas TPU kernel: fused activation quantization (f32/bf16 -> int codes).

Deploy-time activations are quantized on the fly with the QAT-learned
per-tensor scale (paper: true k-bit activation grids). Fusing the
divide/clamp/round into one VMEM pass halves activation HBM traffic vs
quantize-then-store-f32: the fp activation is read once, the int8 code
written once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256


def _kernel(x_ref, s_ref, out_ref, *, qmin: int, qmax: int):
    z = x_ref[...].astype(jnp.float32) / s_ref[0, 0]
    z = jnp.clip(jnp.round(z), qmin, qmax)
    out_ref[...] = z.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def act_quant_pallas(x: jax.Array, s: jax.Array, *, bits: int = 8,
                     bm: int = DEFAULT_BM, interpret: bool = False):
    """x: (M, K) float -> (M, K) int8 codes on the paper's k-bit grid.

    M is arbitrary (serving batches batch x seq rows): ragged M is padded up
    to a multiple of the row block and the pad rows sliced off the result —
    quantization is elementwise per row, so pad rows never leak.
    """
    M, K = x.shape
    from ..core.quantizer import qrange
    qmin, qmax = qrange(bits)
    bm = min(bm, M)
    Mp = M if M % bm == 0 else (M // bm + 1) * bm
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, qmin=qmin, qmax=qmax),
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, K), jnp.int8),
        interpret=interpret,
    )(x, s.reshape(1, 1))
    return out[:M] if Mp != M else out
