"""KV-cache quantization + nibble helpers shared with the matmul kernels.

The serving KV cache (DESIGN.md §8) stores K/V as integer codes with
per-head, per-token scales:

    codes[..., h, :] = round(x[..., h, :] / s[..., h])    s = amax_hd(|x|) / qmax

* ``kv_bits=8``: int8 codes on the symmetric [-127, 127] grid.
* ``kv_bits=4``: the paper's k=4 grid clamped symmetric to [-7, 7] and packed
  two codes per byte along head_dim (bias +7 into unsigned nibbles, same
  byte layout as the int4 weight packing in ``core/packing`` /
  ``kernels/int4_matmul`` — only the packing axis differs: head_dim here,
  the contracting K axis there).

Per-token granularity means appending one decode step's K/V never touches
another row's scale — quantize-on-append composes with the per-slot scatter
writes that keep serving slots isolated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT4_BIAS = 7  # maps [-7, 8] -> [0, 15]; mirrors core.packing.INT4_BIAS


def kv_qmax(bits: int) -> int:
    """Symmetric clamp bound: 127 for int8, 7 for int4 (|qmin| of the paper's
    asymmetric [-7, 8] grid, so negative outliers are never clipped harder
    than positive ones)."""
    if bits == 8:
        return 127
    if bits == 4:
        return 7
    raise ValueError(f"kv_bits must be 4 or 8, got {bits}")


def unpack_nibbles_rows(wp: jax.Array) -> jax.Array:
    """(K/2, N) uint8 -> (K, N) int8 in [-7, 8]; row 2i from the low nibble.

    The int4 weight-matmul kernels unpack their HBM slabs with this (packing
    along the contracting axis = rows of the weight block).
    """
    lo = (wp & 0xF).astype(jnp.int8) - INT4_BIAS
    hi = (wp >> 4).astype(jnp.int8) - INT4_BIAS
    kk, n = wp.shape
    return jnp.stack([lo, hi], axis=1).reshape(kk * 2, n)


def pack_nibbles_last(codes: jax.Array) -> jax.Array:
    """(..., d) int codes in [-7, 8] -> (..., d/2) uint8; element 2i in the
    low nibble. ``d`` must be even (head_dim always is with RoPE)."""
    d = codes.shape[-1]
    assert d % 2 == 0, f"pack axis extent must be even, got {d}"
    biased = (codes.astype(jnp.int32) + INT4_BIAS).astype(jnp.uint8)
    lo = biased[..., 0::2]
    hi = biased[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles_last(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_nibbles_last`: (..., d/2) uint8 -> (..., d) int8."""
    lo = (packed & 0xF).astype(jnp.int8) - INT4_BIAS
    hi = (packed >> 4).astype(jnp.int8) - INT4_BIAS
    stacked = jnp.stack([lo, hi], axis=-1)          # (..., d/2, 2)
    return stacked.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def quantize_kv(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Quantize K or V rows with per-head, per-token scales.

    x: (..., H, hd) float -> (codes, scales) with
      codes:  (..., H, hd) int8          for bits=8
              (..., H, hd/2) uint8       for bits=4 (packed nibbles)
      scales: (..., H) f32, amax over head_dim / qmax (eps-floored so all-zero
              rows — cache padding — quantize to exact zeros).
    """
    qmax = kv_qmax(bits)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scales = jnp.maximum(amax / qmax, 1e-8)
    codes = jnp.clip(jnp.round(xf / scales[..., None]), -qmax, qmax
                     ).astype(jnp.int8)
    if bits == 4:
        return pack_nibbles_last(codes), scales
    return codes, scales


def dequantize_kv(codes: jax.Array, scales: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """(codes, scales) -> (..., H, hd) float. The code dtype carries the bit
    width: uint8 rows are packed int4 nibbles, int8 rows are bare codes."""
    if codes.dtype == jnp.uint8:
        codes = unpack_nibbles_last(codes)
    return (codes.astype(jnp.float32) * scales[..., None]).astype(dtype)


def kv_code_shape(hd: int, bits: int) -> int:
    """Trailing (head_dim) extent of the code buffer for one K/V row."""
    if bits == 4:
        assert hd % 2 == 0, f"int4 KV packing needs even head_dim, got {hd}"
        return hd // 2
    return hd


def kv_code_dtype(bits: int):
    return jnp.uint8 if bits == 4 else jnp.int8


def kv_buffer_keys(bits: int) -> tuple[str, ...]:
    """The K/V buffer names of a cache state at this precision — the keys a
    row-copy (slot scatter, prefix-cache entry) must carry alongside 'len'.
    Shared by serving/kv_cache and serving/prefix_cache so the packed layout
    is spelled out exactly once."""
    if bits in (8, 4):
        return ("k_q", "v_q", "k_scale", "v_scale")
    if bits == 16:
        return ("k", "v")
    raise ValueError(f"kv_bits must be 16, 8 or 4, got {bits}")


def kv_row_bytes(n_kv: int, hd: int, bits: int, *,
                 fp_bytes: int = 4) -> int:
    """Bytes one cached token row costs across K+V per layer: codes + per-
    (token, head) f32 scales for bits 8/4, plain fp rows for 16. This is the
    quantity the prefix cache's byte budget buys — int4 rows are ~7x smaller
    than f32, so the same budget holds ~7x more reusable prefix tokens."""
    if bits == 16:
        return 2 * n_kv * hd * fp_bytes
    return 2 * (n_kv * kv_code_shape(hd, bits) + n_kv * 4)
