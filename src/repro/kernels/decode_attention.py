"""Pallas TPU kernel: fused decode attention over a quantized KV cache.

One decode step attends a single new token per slot against that slot's
cached K/V (DESIGN.md §8). With the cache quantized (int8, or int4 nibbles
packed along head_dim), the dominant HBM stream of a decode step — reading
S_max * Hkv * hd K/V floats per layer — drops 4-8x: the kernel DMAs the
*packed* codes plus one f32 scale per (token, head) row and dequantizes
blocks in VMEM inside the online-softmax loop. The fp32 (B, S) score matrix
never exists in HBM either.

Layout: grid (B, Hkv); each program owns one (slot, kv-head) pair and the
``group`` query heads mapped to it (GQA). The loop walks the cache in
``bs``-row blocks carrying (acc, m, l); rows at positions >= the slot's
cursor are masked (per-slot lengths — serving refills slots independently).
The current token's K/V arrive unquantized and are folded in after the loop:
the new token attends itself at full precision, and the cache write
(quantize-on-append, models/transformer.write_new_kv) decides what future
steps see.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kv_pack import unpack_nibbles_last

NEG_INF = -2.0e38
DEFAULT_BS = 128


def _dequant_rows(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """(bs, dhp) codes + (bs,) scales -> (bs, dh) f32 rows in VMEM."""
    if codes.dtype == jnp.uint8:
        codes = unpack_nibbles_last(codes)
    return codes.astype(jnp.float32) * scales[:, None]


def _kernel(q_ref, kq_ref, vq_ref, ks_ref, vs_ref, kn_ref, vn_ref, len_ref,
            o_ref, *, bs: int, scale: float):
    S = kq_ref.shape[1]
    n_blk = S // bs
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, dh)
    G, dh = q.shape
    ln = len_ref[0, 0]

    def body(j, carry):
        acc, m, l = carry
        k = _dequant_rows(kq_ref[0, pl.ds(j * bs, bs), 0, :],
                          ks_ref[0, pl.ds(j * bs, bs), 0])       # (bs, dh)
        v = _dequant_rows(vq_ref[0, pl.ds(j * bs, bs), 0, :],
                          vs_ref[0, pl.ds(j * bs, bs), 0])
        s = q @ k.T                                              # (G, bs)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
        s = jnp.where(pos < ln, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((G, dh), jnp.float32)
    m = jnp.full((G,), NEG_INF, jnp.float32)
    l = jnp.zeros((G,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_blk, body, (acc, m, l))

    # fold in the current token (fp K/V; it always attends itself)
    kn = kn_ref[0, 0].astype(jnp.float32)                # (dh,)
    vn = vn_ref[0, 0].astype(jnp.float32)
    s_n = q @ kn                                         # (G,)
    m_new = jnp.maximum(m, s_n)
    p_n = jnp.exp(s_n - m_new)
    corr = jnp.exp(m - m_new)
    l = l * corr + p_n
    acc = acc * corr[:, None] + p_n[:, None] * vn[None, :]
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_pallas(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                            k_scale: jax.Array, v_scale: jax.Array,
                            k_new: jax.Array, v_new: jax.Array,
                            lengths: jax.Array, *, bs: int = DEFAULT_BS,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, dh) float; k_q/v_q: (B, S, Hkv, dhp) int8 (dhp=dh) or uint8
    packed nibbles (dhp=dh/2); k_scale/v_scale: (B, S, Hkv) f32 per-row
    scales; k_new/v_new: (B, Hkv, dh) float; lengths: (B,) int32 per-slot
    cursors. Returns (B, H, dh) in q.dtype."""
    B, H, dh = q.shape
    S, Hkv = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    assert H % Hkv == 0, (H, Hkv)
    assert S % bs == 0, (S, bs)
    scale = 1.0 / float(dh) ** 0.5
    qg = q.reshape(B, Hkv, group, dh)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, group, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, S, 1, k_q.shape[-1]), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1, v_q.shape[-1]), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, S, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, 1, dh), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        interpret=interpret,
    )(qg, k_q, v_q, k_scale, v_scale, k_new, v_new, lens)
    return out.reshape(B, H, dh)


# ------------------------------------------------------- paged indirection
def gather_kv_blocks(buf: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Block-pool buffer (NB, block, ...) + per-slot tables (B, nb) ->
    dense-layout view (B, nb*block, ...).

    ``mode='clip'`` clamps out-of-range table entries (the pool pads
    tables with its ``num_blocks`` sentinel) — jnp.take's default fill
    mode would inject NaN, which survives even fully-masked positions as
    ``0 * NaN``. Clamped positions surface arbitrary resident rows — safe
    by the same argument that makes the dense layout's stale rows safe:
    every position >= the slot's length is replaced with ``NEG_INF``
    before the softmax (``_kernel`` above and the jnp reference path
    alike), so garbage rows contribute *exact zeros* to the output,
    keeping paged bit-identical to dense."""
    g = jnp.take(buf, block_tables, axis=0,
                 mode="clip")                      # (B, nb, block, ...)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_paged(q: jax.Array, k_q_blocks: jax.Array,
                           v_q_blocks: jax.Array, k_scale_blocks: jax.Array,
                           v_scale_blocks: jax.Array,
                           block_tables: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, lengths: jax.Array, *,
                           bs: int = DEFAULT_BS,
                           interpret: bool = False) -> jax.Array:
    """Paged-layout entry point: one per-layer gather of block indices,
    then the UNCHANGED in-VMEM dequant online-softmax loop.

    ``*_blocks`` are block-pool buffers for ONE layer, (NB, block, Hkv, ...)
    — the pool's layer-major (L, NB, ...) arrays indexed at a layer.
    ``block_tables`` is (B, nb) int32 with nb*block == the dense S (a
    multiple of ``bs`` after the engine's bucket rounding). Output is
    bit-identical to ``decode_attention_pallas`` on the dense layout the
    tables describe. The jnp reference path gets the same indirection one
    level up: the engine gathers a dense-shaped cache view per step (see
    ``serving/block_pool.py``) and feeds the existing reference attention.
    """
    k_q = gather_kv_blocks(k_q_blocks, block_tables)
    v_q = gather_kv_blocks(v_q_blocks, block_tables)
    k_scale = gather_kv_blocks(k_scale_blocks, block_tables)
    v_scale = gather_kv_blocks(v_scale_blocks, block_tables)
    return decode_attention_pallas(q, k_q, v_q, k_scale, v_scale,
                                   k_new, v_new, lengths, bs=bs,
                                   interpret=interpret)
