"""Pallas TPU kernel: causal flash attention (forward) with GQA.

The jnp-level chunked attention (models/attention.py) is numerically right
but materializes every (chunk x chunk) score/probability block in HBM — the
dry-run roofline shows attention score traffic DOMINATING the memory term of
prefill cells. This kernel keeps the whole online-softmax state (scores,
probs, m/l accumulators) in VMEM: HBM traffic collapses to q/k/v reads and
the output write, turning the S^2 byte term into an S^2 FLOP term (where the
MXU is the limiter, not HBM).

Layout: grid (B*H, S/bq); each step owns one (bq, dh) query block and loops
over KV blocks 0..current (causal) with `fori_loop`, carrying (acc, m, l) in
VREGs/VMEM. K/V arrive via BlockSpecs indexed by the batch-head program id;
GQA is handled by mapping query-head h to kv-head h // group.

Block defaults: bq=bk=512, dh up to 256 -> VMEM = q(512*dh) + k/v blocks
(2*512*dh) + scores f32 (512*512*4 = 1 MiB) + acc — ~2-3 MiB, comfortably
within the ~16 MiB budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38
DEFAULT_BQ = 512
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    S = k_ref.shape[1]
    n_k = S // bk
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
    dh = q.shape[-1]

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # (bk, dh)
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                           # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((bq, dh), jnp.float32)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    # causal: kv blocks strictly after this q block contribute nothing
    upper = (qi + 1) * bq // bk if causal else n_k
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc, m, l))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, dh); k/v: (B, S, Hkv, dh) with H % Hkv == 0."""
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0 and bq % bk == 0, (S, bq, bk)
    scale = 1.0 / float(dh) ** 0.5
    # (B*H, S, dh) query layout; kv mapped via h // group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal, scale=scale),
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, S, dh), lambda bh, i, g=group: (bh // g, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda bh, i, g=group: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
