"""Pallas TPU kernel: packed-int4 weight matmul — the paper's deployed layer.

TPU adaptation of MKQ-BERT's int4 CUDA GEMM (DESIGN.md §3): weights live in
HBM as packed nibbles (two int4 codes per byte along K, bias +7 so the paper's
[-7, 8] grid maps to [0, 15]). Each grid step:

  1. DMA a (bk/2, bn) uint8 weight slab HBM->VMEM      (half the int8 bytes!)
  2. VPU nibble unpack -> (bk, bn) int8 (shift/mask, interleave via reshape)
  3. MXU int8 x int8 -> int32 accumulate into VMEM scratch
  4. last K step: fused dequant epilogue  acc * (s_a * s_w[n])

The memory win is exactly what the paper's 15x/1.25x monetizes: decode-time
linear layers are weight-bandwidth-bound, and int4 halves the dominant HBM
stream vs int8 (8x vs f32). Compute stays on the MXU's native int8 path since
TPUs have no int4 ALU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kv_pack import INT4_BIAS, unpack_nibbles_rows as _unpack_nibbles

__all__ = ["INT4_BIAS", "int4_matmul_pallas", "int4_matmul_fused_pallas"]

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _apply_epilogue(r: jax.Array, act: str) -> jax.Array:
    """f32 epilogue activation; mirrors models.layers.act_fn exactly."""
    if act == "gelu":
        return jax.nn.gelu(r, approximate=True)
    if act == "relu":
        return jnp.maximum(r, 0.0)
    raise ValueError(f"unsupported fused activation {act!r}")


def _kernel(x_ref, wp_ref, sa_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w8 = _unpack_nibbles(wp_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = sa_ref[0, 0] * sw_ref[...]
        out_ref[...] = (acc_ref[...].astype(jnp.float32) * scale
                        ).astype(out_ref.dtype)


def _fused_kernel(x_ref, wp_ref, sa_ref, sw_ref, b_ref, out_ref, acc_ref, *,
                  n_k: int, act: str):
    """int4 matmul with the full decode-layer epilogue fused: the int32
    accumulator is dequantized, biased and activated in VMEM on the last K
    step — the (bm, bn) float intermediate never round-trips HBM (the
    two-kernel path pays 2x(M, N) f32 of traffic for bias+act)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w8 = _unpack_nibbles(wp_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = sa_ref[0, 0] * sw_ref[...]
        r = acc_ref[...].astype(jnp.float32) * scale
        r = r + b_ref[...]
        if act != "none":
            r = _apply_epilogue(r, act)
        out_ref[...] = r.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def int4_matmul_pallas(x8: jax.Array, wp: jax.Array, s_a: jax.Array,
                       s_w: jax.Array, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """x8: (M, K) int8 (int4-grid codes), wp: (K/2, N) uint8 packed nibbles,
    s_a: () f32 activation scale, s_w: (1, N) f32 per-out-channel scales."""
    M, K = x8.shape
    Kp, N = wp.shape
    assert Kp * 2 == K, (x8.shape, wp.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and bk % 2 == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x8, wp, s_a.reshape(1, 1), s_w)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int4_matmul_fused_pallas(x8: jax.Array, wp: jax.Array, s_a: jax.Array,
                             s_w: jax.Array, bias: jax.Array, *,
                             act: str = "none", bm=DEFAULT_BM, bn=DEFAULT_BN,
                             bk=DEFAULT_BK, out_dtype=jnp.float32,
                             interpret: bool = False) -> jax.Array:
    """Fused decode path: int4 matmul + dequant + bias + activation epilogue.

    Same operands as :func:`int4_matmul_pallas` plus ``bias: (1, N) f32`` and
    a static ``act`` ('none' | 'gelu' | 'relu'). The epilogue runs in f32, so
    for f32 outputs the result is bit-identical to the unfused composition
    (matmul kernel -> +bias -> act_fn) while writing the (M, N) intermediate
    to HBM exactly once instead of three times.
    """
    M, K = x8.shape
    Kp, N = wp.shape
    assert Kp * 2 == K, (x8.shape, wp.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and bk % 2 == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fused_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x8, wp, s_a.reshape(1, 1), s_w, bias)
