"""Sharding rules: param-path regex -> PartitionSpec (DP/TP/EP + batch DP).

Mesh axes: single-pod ("data", "model") = (16, 16); multi-pod
("pod", "data", "model") = (2, 16, 16). Parameters are TP-sharded over
"model"; the batch is DP-sharded over ("pod", "data"). The pod axis carries
no parameter shards — cross-pod traffic is gradient reduction only
(hierarchical, DCN-friendly).

Column-parallel (out-dim "model"): wq/wk/wv, ffn w1/w3, up-projections,
expert w1/w3, vocab-sharded embedding. Row-parallel (in-dim "model"):
wo, ffn w2, down/out projections, expert w2 — GSPMD inserts the psum.
Quantization scales follow their weight's out-channel sharding. Everything
small (norms, gates, conv, biases of row-parallel layers) is replicated.
"""
from __future__ import annotations

import re
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on 'a/b/c' joined path, spec for the LAST ndims; left-padded w/ None)
_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$", ("model", None)),
    (r"(^|/)pos_embed$", (None, None)),
    (r"(^|/)lm_head$", (None, "model")),
    (r"(^|/)router$", (None, None)),
    (r"(^|/)(wq|wk|wv|w1|w3|wqkv|w13|up|in_z|in_x|w_in)/(w|wq)$", (None, "model")),
    (r"(^|/)(wq|wk|wv|w1|w3|wqkv|w13|up|in_z|in_x|w_in)/s_w$", (None, "model")),
    (r"(^|/)(wq|wk|wv|w1|w3|wqkv|w13|up|in_z|in_x|w_in)/b$", ("model",)),
    (r"(^|/)(wo|w2|down|out_proj)/(w|wq)$", ("model", None)),
    (r"(^|/)w_gates/w$", (None, None)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for(path, leaf) -> P:
    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    s = _path_str(path)
    for pat, tail in _RULES:
        if re.search(pat, s):
            tail = tail[-ndim:] if ndim < len(tail) else tail
            pad = (None,) * (ndim - len(tail))
            return P(*(pad + tuple(tail)))
    return P(*((None,) * ndim))


def param_specs(params, fsdp_axes: tuple = (), fsdp_min_dim: int = 2) -> dict:
    """Pytree of PartitionSpec matching ``params`` structure.

    ``fsdp_axes``: ZeRO-style weight/optimizer sharding — stacked-layer
    leaves additionally shard their LEADING (layer) dim over these axes when
    divisible. The per-layer dynamic-slice inside the scan then all-gathers
    one layer's shard at use (FSDP semantics); gradients arrive reduce-
    scattered. Cuts params+Adam memory by the data-axis size.
    """
    def spec(p, l):
        s = spec_for(p, l)
        if fsdp_axes and l.ndim > fsdp_min_dim and s[0] is None:
            # leading dim is a layer/group stack dim for every >2D leaf;
            # fall back to an axis subset when the stack doesn't divide the
            # full DP product (e.g. 80 layers on pod*data = 32 -> data = 16)
            for k in range(len(fsdp_axes)):
                axes = fsdp_axes[k:]
                if l.shape[0] % _axes_size(axes) == 0:
                    return P(axes if len(axes) > 1 else axes[0], *s[1:])
        return s
    return jax.tree_util.tree_map_with_path(spec, params)


_AXSZ: dict = {}


def set_mesh_axis_sizes(mesh: Mesh):
    global _AXSZ
    _AXSZ = {a: mesh.shape[a] for a in mesh.axis_names}


def _axes_size(axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= _AXSZ.get(a, 1)
    return n


def batch_spec(mesh: Mesh, ndim: int, batch_axis: int = 0) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = [None] * ndim
    spec[batch_axis] = dp if len(dp) > 1 else dp[0]
    return P(*spec)


def safe_batch_spec(mesh: Mesh, shape: tuple, batch_axis: int = 0) -> P:
    """batch_spec, dropping DP sharding when the batch doesn't divide
    (long_500k has global_batch=1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if shape[batch_axis] % n_dp != 0:
        return P(*((None,) * len(shape)))
    return batch_spec(mesh, len(shape), batch_axis)


def state_specs(state_tree, mesh: Mesh) -> dict:
    """NamedShardings for decode state, shape/divisibility-aware.

    KV caches (..., B, S, H, dh): batch over DP when divisible; the model
    axis goes on HEADS when the head count divides it, else on the SEQUENCE
    dim (context-parallel decode: each model shard holds a cache stripe,
    scores computed locally, GSPMD reduces the tiny softmax/output terms).
    SSM/mLSTM states: batch over DP; inner (channel/value) dim over model
    when divisible (consistent with column-parallel value projections).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dpa = dp if len(dp) > 1 else dp[0]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)

    def spec(path, leaf):
        ndim = leaf.ndim
        shape = leaf.shape
        s = _path_str(path)
        if ndim == 0 or "len" in s:
            return P(*((None,) * ndim))
        sp = [None] * ndim
        if s.endswith("/k") or s.endswith("/v") or s in ("k", "v"):
            b_dim, s_dim, h_dim = ndim - 4, ndim - 3, ndim - 2
            if shape[b_dim] % n_dp == 0:
                sp[b_dim] = dpa
            if shape[h_dim] % n_model == 0:
                sp[h_dim] = "model"
            elif shape[s_dim] % n_model == 0:
                sp[s_dim] = "model"
            return P(*sp)
        if "conv" in s:          # (..., B, K, C): channels over model
            if shape[ndim - 3] % n_dp == 0:
                sp[ndim - 3] = dpa
            if shape[ndim - 1] % n_model == 0:
                sp[ndim - 1] = "model"
            return P(*sp)
        if s.endswith("ssm") or "/C" in s or s.endswith("C"):
            # (..., B, H, P, N) or mlstm C (..., B, H, dk, dv)
            if ndim >= 4 and shape[ndim - 4] % n_dp == 0:
                sp[ndim - 4] = dpa
            if s.endswith("C") and shape[ndim - 1] % n_model == 0:
                sp[ndim - 1] = "model"   # value dim (wv col-parallel)
            elif shape[ndim - 3] % n_model == 0:
                sp[ndim - 3] = "model"   # heads
            return P(*sp)
        # generic small states (n/m/h/c): batch over DP only
        for d in range(ndim):
            size_ok = shape[d] % n_dp == 0 and shape[d] >= n_dp
            if size_ok and d >= ndim - 3 and shape[d] > 1:
                sp[d] = dpa
                break
        return P(*sp)
    return jax.tree_util.tree_map_with_path(spec, state_tree)


# ---------------------------------------------------- serving (tp) specs
# DESIGN.md §16: tensor-parallel serving reuses the training _RULES for the
# packed weight tree (column-parallel wq/wk/wv/wqkv/w1/w3/w13, row-parallel
# wo/w2 — GSPMD inserts the int32 psum), with two serving-only overrides and
# a KV-head rule the training state_specs never needed.

#: replicated in serving regardless of the training rule: logits feed the
#: fp sampler, whose reduction order must match tp=1 EXACTLY for the
#: byte-identical-streams bar — so the lm_head matmul (and the embedding
#: gather feeding it through tied weights) runs replicated. Both are a
#: small fraction of the int4 footprint; vocab sharding is a training
#: memory concern, not a serving one.
_SERVING_REPLICATED = re.compile(r"(^|/)(embed|pos_embed|lm_head)$")


def serving_param_specs(params) -> dict:
    """PartitionSpec tree for a DEPLOYED (packed-int) param tree under the
    serving ("model",) mesh.

    Same regex table as training ``param_specs`` — packed codes keep their
    weight's spec: column-parallel shards the out dim N (nibbles pack along
    K, so N-sharding never splits a pair); row-parallel shards the PACKED
    K/2 rows (divisibility enforced at plan build). Scales ``s_w`` (1, N)
    follow their weight's out-channel sharding; activation scales ``s_a``
    and row-parallel biases fall through to replicated.
    """
    def spec(path, leaf):
        if _SERVING_REPLICATED.search(_path_str(path)):
            ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
            return P(*((None,) * ndim))
        return spec_for(path, leaf)
    return jax.tree_util.tree_map_with_path(spec, params)


def serving_state_specs(state_tree, mesh: Mesh) -> dict:
    """KV-head partitioning for the serving decode state and the paged
    block-pool buffers (DESIGN.md §16).

    The training ``state_specs`` rule only knows the fp ``k``/``v`` rows;
    serving also carries the quantized layout (DESIGN.md §8):

    ===============  ==============================  =====================
    leaf             shape                            "model" axis
    ===============  ==============================  =====================
    k / v            (L, B, S, H_kv, hd)              heads (ndim-2)
    k_q / v_q        (L, B, S, H_kv, ceil(hd/2))      heads (ndim-2)
    k_scale/v_scale  (L, B, S, H_kv)                  heads (ndim-1)
    len / cursors    host-side or per-slot ints       replicated
    ===============  ==============================  =====================

    KV codes pack along head_dim, so head sharding never splits a nibble
    pair. The same basenames cover the block pool's (L, NB, block, H_kv, .)
    buffers. Anything unmatched (or non-divisible) stays replicated —
    correct, just not partitioned.
    """
    n_model = mesh.shape.get("model", 1)

    def spec(path, leaf):
        ndim, shape = leaf.ndim, leaf.shape
        base = _path_str(path).rsplit("/", 1)[-1]
        sp = [None] * ndim
        if base in ("k", "v", "k_q", "v_q") and ndim >= 2 \
                and shape[ndim - 2] % n_model == 0:
            sp[ndim - 2] = "model"
        elif base in ("k_scale", "v_scale") and ndim >= 1 \
                and shape[ndim - 1] % n_model == 0:
            sp[ndim - 1] = "model"
        return P(*sp)
    return jax.tree_util.tree_map_with_path(spec, state_tree)


def place_serving(tree, mesh: Mesh, specs):
    """``device_put`` under NamedShardings — both the initial host→mesh
    placement in ``deploy()`` and the reshard-on-load path (artifacts store
    full logical arrays, so resharding to a different tp is pure
    placement)."""
    return jax.device_put(tree, shardings_for(tree, mesh, specs))


def shardings_for(tree, mesh: Mesh, specs=None):
    specs = specs if specs is not None else param_specs(tree)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def make_mesh(shape: tuple, axes: tuple) -> Mesh:
    return jax.make_mesh(shape, axes)
