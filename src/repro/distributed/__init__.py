from .sharding import (batch_spec, make_mesh, param_specs,  # noqa: F401
                       shardings_for)
from .compression import compressed_grad_mean  # noqa: F401
