"""Gradient compression for cross-pod reduction (beyond-paper, 1000-node).

Two schemes, used inside ``shard_map`` over the DP axes by the DDP train path:

* ``bf16``: reduce in bfloat16 — halves wire bytes vs f32, no state. This is
  the production default (visible in the HLO as bf16 all-reduces).
* ``int8_ef``: int8 quantization with ERROR FEEDBACK (1-bit-Adam style):
  t = g + e;  q = round(t / s) with shared scale s (psum-max);
  reduce int32(q); e' = t - q*s. The residual e' is carried across steps, so
  compression error is compensated rather than accumulated — the same
  mechanism that makes the paper's 4-bit grids trainable, applied to the
  gradient wire format.

Both return the MEAN gradient over the axis, matching an uncompressed psum.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def compressed_grad_mean(grads, axis_names, method: str = "bf16",
                         error_state: Optional[dict] = None):
    """Mean-reduce ``grads`` over mesh ``axis_names`` with compression.

    Must be called inside shard_map with ``axis_names`` manual axes.
    Returns (mean_grads, new_error_state).
    """
    # axis size via psum(1): works on every jax version (lax.axis_size is
    # newer than the pinned 0.4.x line)
    n = jax.lax.psum(1, axis_names)

    if method == "none":
        return jax.tree.map(
            lambda g: jax.lax.pmean(g, axis_names), grads), error_state
    if method == "bf16":
        return jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.bfloat16), axis_names)
            .astype(g.dtype), grads), error_state
    if method != "int8_ef":
        raise ValueError(f"unknown compression {method!r}")

    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)

    def one(g, e):
        t = g.astype(jnp.float32) + e
        # shared scale across the axis so dequant is exact after int32 psum
        s = jax.lax.pmax(jnp.max(jnp.abs(t)), axis_names) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(t / s), -127, 127).astype(jnp.int8)
        e_new = t - q.astype(jnp.float32) * s
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        mean = total.astype(jnp.float32) * s / n
        return mean.astype(g.dtype), e_new

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
