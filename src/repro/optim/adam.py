"""Adam with parameter groups (paper §5.2: distinct lr for weights vs
activation-scales vs weight-scales) — implemented directly in JAX (no optax
in this container).

Groups are resolved from pytree paths: leaves named ``s_a*`` are activation
quantization scales, ``s_w*`` weight quantization scales, everything else is
a weight. Scales are clamped positive after each update (LSQ stability).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


GROUP_WEIGHTS = "weights"
GROUP_ACT_SCALE = "act_scale"
GROUP_W_SCALE = "weight_scale"


def group_for_path(path) -> str:
    keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    for k in reversed(keys):
        if isinstance(k, str) and k.startswith("s_a"):
            return GROUP_ACT_SCALE
        if isinstance(k, str) and k.startswith("s_w"):
            return GROUP_W_SCALE
    return GROUP_WEIGHTS


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.zeros_like, zeros))


def adam_update(params, grads, state: AdamState, *, lr_by_group: dict,
                schedule_fn: Callable, b1=0.9, b2=0.999, eps=1e-8,
                grad_clip: float = 0.0):
    """Returns (new_params, new_state). lr_by_group: group name -> base lr."""
    step = state.step + 1
    sched = schedule_fn(step)

    if grad_clip:
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        factor = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * factor, grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        group = group_for_path(path)
        lr = lr_by_group[group] * sched
        delta = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - delta
        if group in (GROUP_ACT_SCALE, GROUP_W_SCALE):
            p_new = jnp.maximum(p_new, 1e-8)  # scales stay positive
        return p_new.astype(p.dtype), m_new, v_new

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    gl = jax.tree.leaves(grads)
    ml = jax.tree.leaves(state.m)
    vl = jax.tree.leaves(state.v)
    out = [upd(path, p, g, m, v)
           for (path, p), g, m, v in zip(flat, gl, ml, vl)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamState(step=step, m=new_m, v=new_v)
