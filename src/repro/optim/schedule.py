"""LR schedules. Paper §5.2: grow linearly for 10% of steps, decay to 0."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_decay(total_steps: int, warmup_frac: float = 0.10):
    warmup = max(1, int(total_steps * warmup_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        up = s / warmup
        down = (total_steps - s) / max(1, total_steps - warmup)
        return jnp.clip(jnp.minimum(up, down), 0.0, 1.0)

    return fn
