from .adam import AdamState, adam_init, adam_update, group_for_path  # noqa: F401
from .schedule import linear_warmup_decay  # noqa: F401
