"""Fault-tolerant checkpointing: atomic, keep-k, auto-resume.

Layout:  <dir>/step_000042/
           shard_00000.npz       (flattened leaf arrays, this host's shard)
           META.json             (treedef paths, step, metric, mesh signature)
         <dir>/LATEST            (atomic pointer file)

Writes go to a temp dir + os.rename (atomic on POSIX), so a crash mid-save
never corrupts the latest checkpoint — the restart path (launch/train.py)
always resumes from a complete step. Multi-host: each host writes only the
leaves it owns (addressable shards); here (single host) that's all leaves.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten(tree_like, arrays: dict[str, np.ndarray]):
    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


# --------------------------------------------------------------- artifacts

def _nest(arrays: dict[str, np.ndarray]):
    """'/'-joined flat keys → nested tree; integer-keyed levels (list indices
    from tree_flatten_with_path's SequenceKey) become lists.

    Unlike ``_unflatten`` this needs NO template tree — the deployed-int
    parameter structure (per-segment stacks, packed-code leaves) is rebuilt
    from the keys alone, so an artifact loads without first constructing a
    model (DESIGN.md §9)."""
    root: dict = {}
    for key, arr in arrays.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def listify(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            idx = sorted(int(k) for k in node)
            if idx == list(range(len(node))):
                return [listify(node[str(i)]) for i in idx]
        return {k: listify(v) for k, v in node.items()}
    return listify(root)


def save_artifact(path: str, tree: Any, meta: dict) -> str:
    """Write a self-describing artifact directory: ``arrays.npz``
    (flattened leaves) + ``ARTIFACT.json`` (meta). Same temp-dir +
    os.rename discipline as checkpoint saves — a crash mid-write never
    publishes a partial artifact. Overwrites move the previous artifact
    aside BEFORE the new one is published (and restore it if the publish
    rename fails), so an existing artifact is never destroyed by a failed
    save; a crash inside the two-rename swap window leaves the previous
    payload recoverable under ``.old_artifact_*``."""
    path = os.path.abspath(path)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    if os.path.exists(path) and not os.path.isdir(path):
        raise ValueError(f"{path} exists and is not an artifact directory")
    tmp = tempfile.mkdtemp(dir=parent, prefix=".tmp_artifact_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "ARTIFACT.json"), "w") as f:
            json.dump({**meta, "time": time.time()}, f, indent=2,
                      sort_keys=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    backup = None
    if os.path.isdir(path):
        backup = tempfile.mkdtemp(dir=parent, prefix=".old_artifact_")
        os.rename(path, os.path.join(backup, "prev"))
    try:
        os.rename(tmp, path)                            # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        if backup is not None:                          # restore the old one
            os.rename(os.path.join(backup, "prev"), path)
        raise
    if backup is not None:
        shutil.rmtree(backup, ignore_errors=True)
    return path


def load_artifact(path: str) -> tuple[Any, dict]:
    """(tree, meta) from :func:`save_artifact`'s layout. Leaves come back as
    numpy arrays with their saved dtypes (packed int codes stay packed)."""
    with open(os.path.join(path, "ARTIFACT.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz"),
                 allow_pickle=False) as z:
        arrays = dict(z)
    return _nest(arrays), meta


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.host = host_index
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, metrics: Optional[dict] = None):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            arrays = _flatten(state)
            np.savez(os.path.join(tmp, f"shard_{self.host:05d}.npz"), **arrays)
            meta = {"step": step, "time": time.time(),
                    "metrics": metrics or {}, "keys": sorted(arrays)}
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                       # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._write_latest(step)
        self._gc()
        return final

    def _write_latest(self, step: int):
        tmp = os.path.join(self.dir, ".LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                step = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{step:09d}")):
                return step
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None):
        """Returns (state, step) or (None, None) when no checkpoint exists."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:09d}")
        data = dict(np.load(os.path.join(d, f"shard_{self.host:05d}.npz"),
                            allow_pickle=False))
        return _unflatten(state_like, data), step
