import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step function (QAT train step with Adam,
or the deployed-int serve step), lowers it with ShapeDtypeStruct inputs under
the production mesh shardings, compiles, and records:

  * memory_analysis()      — per-device bytes (proves the cell fits v5e HBM)
  * cost_analysis()        — XLA's own (scan-body-once) numbers, for reference
  * hlo_analysis.analyze() — trip-count-corrected per-device FLOPs / HBM bytes
                             / collective bytes (EXPERIMENTS.md methodology)
  * the three roofline terms + dominant bottleneck

Results go to experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback

# TPU v5e hardware model (assignment constants)
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9


def _build_cell(arch: str, shape_name: str, mesh, *, policy_kind: str,
                distill: bool, grad_mode: str, extra: dict):
    """Returns (step_fn, in_specs_tree, in_shardings_tree, out_shardings)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import SHAPES, get_config, input_specs, shape_applicable
    from ..core.policy import QuantPolicy
    from ..distributed.sharding import (batch_spec, param_specs,
        safe_batch_spec, set_mesh_axis_sizes, state_specs)
    from ..models import api
    from ..models.transformer import lm_loss
    from ..optim import adam_init, adam_update, linear_warmup_decay

    cfg = get_config(arch)
    if extra.get("attn_chunk"):
        cfg = cfg.replace(attn_chunk=extra["attn_chunk"])
    if extra.get("moe_group_size"):
        cfg = cfg.replace(moe_group_size=extra["moe_group_size"])
    if extra.get("remat") is not None:
        cfg = cfg.replace(remat=bool(extra["remat"]))
    if extra.get("attn_seq_shard"):
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        cfg = cfg.replace(attn_seq_shard=True, dp_axes=dp)
    if extra.get("fused_proj"):
        cfg = cfg.replace(fused_proj=True)
    if extra.get("moe_sorted"):
        cfg = cfg.replace(moe_impl="sorted")
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why

    n_units = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    k_int4 = {"mkq50": n_units // 2, "int8": 0, "int4": n_units}[policy_kind]

    kv_dtype = jnp.dtype(extra.get("kv_dtype", "bfloat16"))
    sh = lambda spec: NamedSharding(mesh, spec)
    set_mesh_axis_sizes(mesh)
    fsdp_axes = ()
    if extra.get("fsdp"):
        fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if shape.kind == "train":
        policy = QuantPolicy(num_layers=n_units, mode="fake",
                             last_k_int4=k_int4, grad_mode=grad_mode)
        segments = api.segments_for(cfg, policy)
        hp_lr = {"weights": 1e-5, "act_scale": 0.01, "weight_scale": 0.001}
        sched = linear_warmup_decay(10000)
        key = jax.random.PRNGKey(0)
        params = jax.eval_shape(lambda k: api.init_model(cfg, k), key)
        opt = jax.eval_shape(adam_init, params)
        batch = input_specs(cfg, shape)

        def model_inputs(b):
            return {k: v for k, v in b.items() if k != "labels"}

        n_micro = int(extra.get("microbatch") or 1)
        teacher = None
        t_segments = None
        if distill:  # paper-faithful QAT step: fp teacher + MINI distillation
            teacher = jax.eval_shape(lambda k: api.init_model(cfg, k),
                                     jax.random.fold_in(key, 7))
            t_segments = api.segments_for(cfg, None)

        def grads_of(p, b, t=None):
            def loss_fn(pp):
                logits, _, taps_s, aux = api.forward(
                    pp, cfg, segments, want_taps=distill, **model_inputs(b))
                l_train = lm_loss(logits, b["labels"]) + aux
                if not distill:
                    return l_train
                from ..core.distill import (combine_losses,
                                            hidden_state_loss,
                                            minilm_losses, output_loss)
                t_logits, _, taps_t, _ = api.forward(
                    t, cfg, t_segments, want_taps=True, **model_inputs(b))
                taps_t = jax.lax.stop_gradient(taps_t)
                l_out = output_loss(logits, jax.lax.stop_gradient(t_logits))
                if taps_s is not None and "q" in taps_s:
                    l_attn, l_val = minilm_losses(
                        taps_s, taps_t, min(cfg.num_heads, 16))
                else:
                    l_attn = hidden_state_loss(taps_s["hidden"],
                                               taps_t["hidden"])
                    l_val = jnp.zeros(())
                total, _ = combine_losses(l_train, l_out, l_attn, l_val)
                return total
            return jax.value_and_grad(loss_fn)(p)

        def train_step(p, o, b, t=None):
            if n_micro > 1:
                # grad accumulation: microbatch i+1's compute overlaps the
                # reduce of microbatch i (XLA latency-hiding scheduler).
                # keep the batch dim sharded over DP after the micro reshape
                mb = jax.tree.map(
                    lambda a: jax.lax.with_sharding_constraint(
                        a.reshape(n_micro, a.shape[0] // n_micro,
                                  *a.shape[1:]),
                        NamedSharding(mesh, batch_spec(mesh, a.ndim + 1,
                                                       batch_axis=1))), b)

                def micro(acc, bi):
                    loss_i, g_i = jax.remat(grads_of)(p, bi, t)
                    return (jax.tree.map(jnp.add, acc[0], g_i),
                            acc[1] + loss_i), None

                zero = jax.tree.map(
                    lambda a: jnp.zeros(a.shape, jnp.float32), p)
                (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mb)
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                loss = lsum / n_micro
            else:
                loss, grads = grads_of(p, b, t)
            new_p, new_o = adam_update(p, grads, o, lr_by_group=hp_lr,
                                       schedule_fn=sched, grad_clip=1.0)
            return new_p, new_o, loss

        pspec = param_specs(params, fsdp_axes=fsdp_axes)
        psh = jax.tree.map(lambda s: sh(s), pspec,
                           is_leaf=lambda x: isinstance(x, P))
        osh_mv = jax.tree.map(lambda s: sh(s), pspec,
                              is_leaf=lambda x: isinstance(x, P))
        from ..optim.adam import AdamState
        osh = AdamState(step=sh(P()), m=osh_mv, v=jax.tree.map(
            lambda s: s, osh_mv))
        bsh = {k: sh(safe_batch_spec(mesh, v.shape)) for k, v in batch.items()}
        if distill:
            tsh = jax.tree.map(lambda s: sh(s), param_specs(teacher),
                               is_leaf=lambda x: isinstance(x, P))
            fn = jax.jit(train_step, in_shardings=(psh, osh, bsh, tsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
            return (fn, (params, opt, batch, teacher)), None
        fn = jax.jit(train_step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        return (fn, (params, opt, batch)), None

    # ---------------- inference cells: deployed int path -------------------
    policy = QuantPolicy(num_layers=n_units, mode="int",
                         last_k_int4=k_int4, grad_mode=grad_mode)
    segments = api.segments_for(cfg, policy)
    key = jax.random.PRNGKey(0)

    def make_int_params(k):
        from ..core.qat import deploy_params
        return deploy_params(api.init_model(cfg, k), cfg, segments)

    params = jax.eval_shape(make_int_params, key)
    pspec = param_specs(params)
    psh = jax.tree.map(lambda s: sh(s), pspec,
                       is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)

        def prefill_step(p, b):
            logits, _, _, _ = api.forward(p, cfg, segments, **b)
            return logits

        bsh = {k: sh(safe_batch_spec(mesh, v.shape)) for k, v in batch.items()}
        fn = jax.jit(prefill_step, in_shardings=(psh, bsh),
                     out_shardings=None)
        return (fn, (params, batch)), None

    # decode: one token against a cache of seq_len
    B, S = shape.global_batch, shape.seq_len
    state = api.decode_state(cfg, B, S, dtype=kv_dtype, as_specs=True)
    ssh = jax.tree.map(lambda s: sh(s), state_specs(state, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tsh = sh(safe_batch_spec(mesh, (B, 1)))
    extra_in = api.decode_extra_inputs(cfg, B, S, dtype=cfg.compute_dtype,
                                       as_specs=True)
    esh = {k: sh(safe_batch_spec(mesh, v.shape)) for k, v in extra_in.items()}

    def serve_step(p, st, tok, ex):
        logits, new_state, _, _ = api.forward(p, cfg, segments, state=st,
                                              tokens=tok, **ex)
        return logits, new_state

    fn = jax.jit(serve_step, in_shardings=(psh, ssh, tsh, esh),
                 out_shardings=(None, ssh), donate_argnums=(1,))
    return (fn, (params, state, tokens, extra_in)), None


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             policy_kind="mkq50", distill=False, grad_mode="mse",
             tag="", extra=None) -> dict:
    import jax
    from .hlo_analysis import analyze
    from .mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    built, skip = _build_cell(arch, shape_name, mesh, policy_kind=policy_kind,
                              distill=distill, grad_mode=grad_mode,
                              extra=extra or {})
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "chips": int(n_chips), "policy": policy_kind,
              "grad_mode": grad_mode, "tag": tag}
    if built is None:
        result["status"] = "skipped"
        result["reason"] = skip
        _dump(result, out_dir)
        return result
    fn, specs = built
    with mesh:
        lowered = fn.lower(*specs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    h = analyze(hlo)

    terms = {
        "compute_s": h["float_flops"] / PEAK_FLOPS_BF16
        + h["int_flops"] / PEAK_FLOPS_INT8,
        "memory_s": h["hbm_bytes"] / HBM_BW,
        "collective_s": h["collective_bytes_total"] / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    result.update({
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
            "fits_16g": bool(mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes < 16e9),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "hlo_analysis": {k: h[k] for k in
                         ("flops", "int_flops", "float_flops", "hbm_bytes",
                          "collective_bytes", "collective_bytes_total",
                          "hbm_by_mult")},
        "top_collectives": h["top_collectives"],
        "top_dots": h["top_dots"][:6],
        "top_hbm": h["top_hbm"],
        "roofline_terms_s": terms,
        "dominant": dom,
    })
    _dump(result, out_dir)
    return result


def _dump(result: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{result['tag']}" if result.get("tag") else ""
    path = os.path.join(out_dir, f"{result['arch']}__{result['shape']}__"
                                 f"{result['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    status = result["status"]
    if status == "ok":
        t = result["roofline_terms_s"]
        print(f"[dryrun] {result['arch']} {result['shape']} {result['mesh']} "
              f"OK compile={result['compile_s']}s "
              f"mem={result['memory']['total_bytes']/1e9:.2f}GB "
              f"compute={t['compute_s']*1e3:.2f}ms mem={t['memory_s']*1e3:.2f}ms "
              f"coll={t['collective_s']*1e3:.2f}ms dom={result['dominant']}",
              flush=True)
    else:
        print(f"[dryrun] {result['arch']} {result['shape']} {result['mesh']} "
              f"{status}: {result.get('reason', '')}", flush=True)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="single", choices=["single", "multi",
                                                        "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--policy", default="mkq50",
                   choices=["mkq50", "int8", "int4"])
    p.add_argument("--grad-mode", default="mse", choices=["mse", "ste"])
    p.add_argument("--tag", default="")
    p.add_argument("--kv-dtype", default="bfloat16")
    p.add_argument("--attn-chunk", type=int, default=0)
    p.add_argument("--moe-group-size", type=int, default=0)
    p.add_argument("--remat", type=int, default=-1)
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--attn-seq-shard", action="store_true")
    p.add_argument("--fsdp", action="store_true")
    p.add_argument("--fused-proj", action="store_true")
    p.add_argument("--distill", action="store_true")
    p.add_argument("--moe-sorted", action="store_true")
    args = p.parse_args(argv)

    from ..configs import SHAPES
    from ..configs.archs import ASSIGNED

    extra = {"kv_dtype": args.kv_dtype, "attn_chunk": args.attn_chunk,
             "moe_group_size": args.moe_group_size,
             "remat": None if args.remat < 0 else args.remat,
             "microbatch": args.microbatch,
             "attn_seq_shard": args.attn_seq_shard,
             "fsdp": args.fsdp, "fused_proj": args.fused_proj,
             "moe_sorted": args.moe_sorted}
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            try:
                run_cell(arch, shape, mk, args.out, policy_kind=args.policy,
                         grad_mode=args.grad_mode, tag=args.tag, extra=extra,
                         distill=args.distill)
            except Exception:
                failures += 1
                print(f"[dryrun] {arch} {shape} {mk} FAILED", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
