"""Elastic scaling: resume training on a different device count.

On restart after node loss, the controller calls :func:`elastic_resume`:
the checkpoint (device-agnostic npz) is loaded, a fresh (data, model) mesh is
built from the LIVE device set (model-parallel degree preserved when the
survivor count allows, else halved), and the global batch is re-split over
the new data axis. Because checkpoints store full logical arrays (host
shards), resharding is just placement under the new mesh — no format change.

The DP-elastic contract: global batch stays FIXED (per-device microbatch
grows), so optimizer hyperparameters remain valid across re-scales.
"""
from __future__ import annotations

import jax

from ..distributed.sharding import shardings_for
from .mesh import make_mesh_for_devices


def elastic_resume(state_like, ckpt_manager, *, model_parallel: int = 0,
                   devices=None):
    """(state, step, mesh) from the latest checkpoint on the live devices."""
    devices = devices if devices is not None else jax.devices()
    # restart contract: model-parallel degree preserved when the survivor
    # count allows, else halved — so degrading is explicitly opted into here
    mesh = make_mesh_for_devices(len(devices), model_parallel,
                                 allow_degrade=True).mesh
    state, step = ckpt_manager.restore(state_like)
    if state is None:
        return None, None, mesh
    shardings = shardings_for(state, mesh)
    state = jax.device_put(state, shardings)
    return state, step, mesh


def rebalance_batch(global_batch: int, mesh) -> int:
    """Per-host batch after a re-scale; raises if the batch can't split."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    if global_batch % dp:
        raise ValueError(f"global batch {global_batch} not divisible by "
                         f"data parallelism {dp} after re-scale")
    return global_batch // dp
