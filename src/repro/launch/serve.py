"""Batched int4/int8 serving driver (the paper's deployment side).

Continuous-batching-lite: requests join a fixed-size slot table; every engine
step decodes one token for all active slots with the deployed integer model
(packed int4/int8 weights + on-the-fly activation quantization); finished
slots are refilled from the queue. Slot state is the per-layer KV cache
(or SSM state), written one token per step (models/*).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServingEngine:
    """Fixed-slot decode engine over the deployed quantized model."""

    def __init__(self, params_int, cfg, segments, *, slots: int = 8,
                 max_len: int = 512, dtype=jnp.float32):
        from ..models import api
        self.api = api
        self.cfg = cfg
        self.segments = segments
        self.params = params_int
        self.slots = slots
        self.max_len = max_len
        self.state = api.decode_state(cfg, slots, max_len, dtype=dtype)
        self.active = [None] * slots          # slot -> Request
        self.generated: list[list[int]] = [[] for _ in range(slots)]
        self.pos = np.zeros(slots, np.int32)  # per-slot prompt cursor
        self.queue: list[Request] = []
        self.done: list[Request] = []

        def step(params, state, tokens):
            logits, new_state, _, _ = api.forward(
                params, cfg, segments, state=state, tokens=tokens)
            return jnp.argmax(logits[:, -1], axis=-1), new_state

        self._step = jax.jit(step, donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                self.active[s] = self.queue.pop(0)
                self.generated[s] = []
                self.pos[s] = 0

    def engine_step(self):
        """One decode step for every active slot (inactive slots run pad)."""
        self._admit()
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if self.pos[s] < len(req.prompt):       # still feeding the prompt
                toks[s, 0] = req.prompt[self.pos[s]]
            elif self.generated[s]:
                toks[s, 0] = self.generated[s][-1]
            else:
                toks[s, 0] = req.prompt[-1]
        next_tok, self.state = self._step(self.params, self.state,
                                          jnp.asarray(toks))
        next_tok = np.asarray(next_tok)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            if self.pos[s] >= len(req.prompt):
                self.generated[s].append(int(next_tok[s]))
                if len(self.generated[s]) >= req.max_new_tokens:
                    req.out = np.array(self.generated[s], np.int32)
                    self.done.append(req)
                    self.active[s] = None

    def run_until_drained(self, max_steps: int = 10000):
        steps = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.engine_step()
            steps += 1
        return steps


def main(argv=None):
    from ..configs import get_config, reduced
    from ..core.policy import QuantPolicy
    from ..core.qat import calibrate_weight_scales, default_bits_fn, \
        deploy_params
    from ..models import api

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--int4-last-k", type=int, default=-1)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_units = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    k4 = args.int4_last_k if args.int4_last_k >= 0 else n_units // 2
    policy = QuantPolicy(num_layers=n_units, mode="int", last_k_int4=k4)
    segments = api.segments_for(cfg, policy)

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    params = calibrate_weight_scales(params, default_bits_fn(cfg, policy))
    params_int = deploy_params(params, cfg, segments)

    eng = ServingEngine(params_int, cfg, segments, slots=args.slots,
                        max_len=128)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(prompt=rng.integers(
            1, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=8))
    steps = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in eng.done)
    print(f"[serve] {len(eng.done)} requests, {total_tokens} tokens, "
          f"{steps} engine steps, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
