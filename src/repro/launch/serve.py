"""Thin CLI shim over the serving subsystem (repro/serving — DESIGN.md
§7/§9/§10).

Entry modes:

* default            build an ExecutionPlan, deploy an int model in-process,
                     serve a synthetic burst (smoke/demo path);
* ``--export DIR``   additionally save the DeployedModel artifact to DIR;
* ``--artifact DIR`` load a previously exported artifact and serve it —
                     no fp weights are initialized and nothing recalibrates;
                     token streams are byte-identical to the in-memory run
                     that exported it;
* ``--mode encoder`` prefill-only serving (DESIGN.md §14): deploys an int4
                     BERT classifier (or loads one with --artifact) and
                     serves a burst of ``EncodeRequest``\\ s (``--task``
                     classify/embed/score) — no decode loop, no KV;
* ``--tenant NAME=DIR`` (repeatable) multi-tenant serving: each NAME loads
                     the artifact at DIR into one ``MultiTenantEngine``
                     (shared clock/metrics, deficit-round-robin fair share);
                     the burst round-robins across tenants, encode traffic
                     for encoder artifacts and generation otherwise.

Generation flags map onto the §10 API: ``--temperature/--top-k/--top-p/
--seed`` build the burst's ``SamplingParams`` (temperature 0 = greedy),
``--n`` fans each prompt into n independently-seeded sample streams,
``--stop`` sets stop-token ids, and ``--stream`` prints each token as the
engine emits it (the TokenStream callback form). ``--kv-paging paged``
(+ optional ``--kv-budget-mb``) serves the burst out of the §15 paged
block pool; ``--policy-from search.json`` deploys the exact per-layer bit
assignment a §13 auto-search run chose.

Scale axes (DESIGN.md §16): ``--tp N`` shards the deployed weights and KV
heads over N devices (with ``--artifact`` it RESHARDS the saved layout to
N at load); ``--replicas N`` serves the burst through a data-parallel
``ReplicaSet`` of N engines over the one deployed model; ``--warmup``
pre-compiles every (bucket, batch) prefill/decode shape before traffic so
the first request pays no jit cost (the first-vs-steady split shows up in
the metrics report).

The engine itself lives in ``repro.serving``; plans/artifacts in
``repro.deploy``. ``Request`` and ``ServingEngine`` stay importable from
here for backward compatibility.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..serving import (EncodeRequest, GenerationRequest,  # noqa: F401
                       MultiTenantEngine, QueueFullError,
                       Request, SamplingParams, ServingEngine)  # (compat)


def _build_encoder_model(args):
    """In-process int4 W4A4 BERT classifier artifact for --mode encoder:
    the paper's deployment target, calibrated on a small synthetic batch."""
    import jax

    from ..core.policy import QuantPolicy
    from ..deploy import ExecutionPlan, deploy
    from ..models.bert import init_bert_classifier, tinybert_config

    cfg = (tinybert_config(layers=4, d=96, heads=4, d_ff=192, vocab=512,
                           name="tinybert4-reduced")
           if args.reduced else tinybert_config())
    n_units = cfg.num_layers
    k4 = args.int4_last_k if args.int4_last_k >= 0 else n_units
    policy = QuantPolicy(num_layers=n_units, mode="int", last_k_int4=k4)
    plan = ExecutionPlan.build(cfg, policy, backend=args.backend,
                               mode="encoder",
                               prefill_batch=max(args.prefill_batch, 1),
                               act_bits=args.act_bits,
                               tp=args.tp or 1)
    params = init_bert_classifier(cfg, 2, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [{"tokens": rng.integers(1, cfg.vocab_size,
                                     (4, 16)).astype(np.int32)}
             for _ in range(4)]
    return deploy(params, plan, calib)


def _build_model(args):
    """ExecutionPlan + in-process deployment (the non-artifact path)."""
    import jax

    from ..configs import get_config, reduced
    from ..core.policy import QuantPolicy
    from ..deploy import ExecutionPlan, deploy
    from ..models import api

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_units = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    if args.policy_from:
        from ..core.autosearch import load_search_policy
        policy = load_search_policy(args.policy_from, n_units)
        print(f"[serve] policy from {args.policy_from}: {policy.describe()}")
    else:
        k4 = args.int4_last_k if args.int4_last_k >= 0 else n_units // 2
        policy = QuantPolicy(num_layers=n_units, mode="int", last_k_int4=k4)
    plan = ExecutionPlan.build(cfg, policy, backend=args.backend,
                               kv_bits=args.kv_bits,
                               prefill_mode=args.prefill_mode,
                               prefix_cache=int(args.prefix_cache_mb
                                                * (1 << 20)),
                               prefill_batch=args.prefill_batch,
                               act_bits=args.act_bits,
                               kv_paging=args.kv_paging,
                               tp=args.tp or 1)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    return deploy(params, plan)


def main(argv=None):
    from ..deploy import DeployedModel

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--mode", default="decode",
                   choices=["decode", "encoder"],
                   help="'encoder' serves prefill-only EncodeRequests "
                        "(DESIGN.md §14) over an int4 BERT classifier "
                        "artifact — one batched bidirectional forward per "
                        "request, no decode loop")
    p.add_argument("--task", default="classify",
                   choices=["classify", "embed", "score"],
                   help="what the --mode encoder burst asks for per request")
    p.add_argument("--tenant", action="append", default=None,
                   metavar="NAME=DIR",
                   help="repeatable: host the artifact at DIR as tenant "
                        "NAME in one MultiTenantEngine (deficit-round-robin "
                        "fair share; encoder and decoder artifacts mix)")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--max-queue", type=int, default=None,
                   help="bound the pending queue (submit raises "
                        "QueueFullError past it; default unbounded)")
    p.add_argument("--int4-last-k", type=int, default=-1)
    p.add_argument("--prefill-mode", default="auto",
                   choices=["auto", "chunked", "token"])
    p.add_argument("--backend", default="reference",
                   choices=["reference", "pallas"],
                   help="'pallas' routes matmuls through the int4/int8 "
                        "Pallas kernels (fused decode epilogue; interpret "
                        "mode off-TPU)")
    p.add_argument("--kv-bits", type=int, default=16, choices=[16, 8, 4],
                   help="serving KV-cache precision (DESIGN.md §8): 16 keeps "
                        "fp rows; 8/4 store packed codes + per-(token, head) "
                        "scales and decode via the fused Pallas "
                        "decode-attention kernel with --backend pallas")
    p.add_argument("--prefix-cache-mb", type=float, default=0.0,
                   help="shared-prefix KV reuse budget in MiB (DESIGN.md "
                        "§11): cached quantized prefix rows scatter into "
                        "new slots and only the prompt suffix prefills; "
                        "0 disables")
    p.add_argument("--kv-paging", default="dense",
                   choices=["dense", "paged"],
                   help="KV-cache memory layout (DESIGN.md §15): 'paged' "
                        "serves slots, shared prefixes and copy-on-write "
                        "forks out of one refcounted block pool under one "
                        "byte budget (admission + LRU eviction), with "
                        "token streams bit-identical to 'dense'")
    p.add_argument("--kv-budget-mb", type=float, default=None,
                   help="paged KV pool byte budget in MiB (requires "
                        "--kv-paging paged); default sizes the pool to "
                        "exactly the dense slots*max_len capacity, so "
                        "flipping --kv-paging alone never changes capacity")
    p.add_argument("--policy-from", default=None, metavar="JSON",
                   help="load the mixed-precision QuantPolicy from a "
                        "search artifact (benchmarks/table1_glue.py "
                        "--search output, or a bare policy dump) instead "
                        "of the --int4-last-k heuristic — serve exactly "
                        "the per-layer bit assignment the auto-search "
                        "chose (DESIGN.md §13)")
    p.add_argument("--n", type=int, default=1,
                   help="samples per burst prompt: n > 1 fans each request "
                        "into n independent streams (seeded per sample "
                        "index); a paged engine shares the prompt's KV "
                        "blocks copy-on-write across the samples")
    p.add_argument("--act-bits", type=int, default=None,
                   choices=[0, 4, 8],
                   help="activation precision override (DESIGN.md §13): "
                        "4/8 quantize every quantized segment's activations "
                        "onto that grid (W4A4 serving; calibrated scales are "
                        "retargeted by the qmax ratio), 0 keeps activations "
                        "fp against dequantized weights (reference backend; "
                        "the parity baseline); default follows the policy. "
                        "With --artifact, retargets the loaded model")
    p.add_argument("--prefill-batch", type=int, default=1,
                   help="group up to N same-bucket admissions into one "
                        "batch-N prefill forward (compiled per (bucket, n), "
                        "n padded to a power of two); 1 keeps serial "
                        "prefills")
    p.add_argument("--shared-prefix", type=int, default=0, metavar="T",
                   help="give every synthetic burst request the same "
                        "T-token prompt prefix (demo workload for "
                        "--prefix-cache-mb)")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="sampling temperature (0 = greedy argmax, the "
                        "legacy path)")
    p.add_argument("--top-k", type=int, default=0,
                   help="keep only the k highest logits (0 disables)")
    p.add_argument("--top-p", type=float, default=1.0,
                   help="nucleus sampling mass (1.0 disables)")
    p.add_argument("--seed", type=int, default=0,
                   help="sampling seed; streams are deterministic per "
                        "(prompt, seed) regardless of batching")
    p.add_argument("--stop", default=None, metavar="ID[,ID...]",
                   help="comma-separated stop-token ids: emitting one ends "
                        "the request early (finish_reason='stop')")
    p.add_argument("--stream", action="store_true",
                   help="print every token as the engine emits it "
                        "(TokenStream callback form)")
    p.add_argument("--tp", type=int, default=None, metavar="N",
                   help="tensor-parallel degree (DESIGN.md §16): shard "
                        "packed weights + KV heads over N devices on a "
                        "('model',) mesh; with --artifact, RESHARDS the "
                        "saved layout to N at load (a tp=2 export serves "
                        "at tp=1 or tp=4); default keeps the recorded "
                        "layout (or 1 when building in-process)")
    p.add_argument("--replicas", type=int, default=1, metavar="N",
                   help="data-parallel replica count (DESIGN.md §16): N "
                        "engines over the ONE deployed model behind one "
                        "admission queue (least-loaded dispatch, shared "
                        "rid space); composes with --tp")
    p.add_argument("--warmup", action="store_true",
                   help="pre-compile every (bucket, batch) prefill/decode "
                        "shape before serving traffic, so no request pays "
                        "first-call jit cost (the first-vs-steady latency "
                        "split stays visible in the metrics report)")
    p.add_argument("--artifact", default=None, metavar="DIR",
                   help="serve a saved DeployedModel (repro.deploy) — no fp "
                        "weights, no recalibration; plan/arch flags come "
                        "from the artifact")
    p.add_argument("--export", default=None, metavar="DIR",
                   help="save the deployed model as an artifact before "
                        "serving (reload later with --artifact DIR)")
    args = p.parse_args(argv)
    if args.artifact and args.export:
        p.error("--export builds a fresh model and cannot be combined with "
                "--artifact (which serves an existing one)")
    if args.artifact and args.kv_paging == "paged":
        p.error("--artifact serves the artifact's own plan (including its "
                "kv_paging axis); export the model with --kv-paging paged "
                "instead of overriding it at load time")
    if args.kv_budget_mb is not None and not args.artifact \
            and args.kv_paging != "paged":
        p.error("--kv-budget-mb sizes the paged KV pool; it needs "
                "--kv-paging paged (or a paged artifact)")
    if args.n < 1:
        p.error(f"--n must be >= 1, got {args.n}")
    if args.tp is not None and args.tp < 1:
        p.error(f"--tp must be >= 1, got {args.tp}")
    if args.replicas < 1:
        p.error(f"--replicas must be >= 1, got {args.replicas}")
    if args.tenant and (args.tp is not None or args.replicas > 1):
        p.error("--tenant engines serve each artifact's own recorded "
                "layout; --tp/--replicas apply to single-model serving")
    if args.tenant:
        if args.artifact or args.export:
            p.error("--tenant hosts saved artifacts; it cannot be combined "
                    "with --artifact/--export")
        return _main_tenants(args)

    if args.artifact:
        model = DeployedModel.load(args.artifact, tp=args.tp)
        if (args.act_bits is not None
                and args.act_bits != model.plan.act_bits):
            from ..deploy import retarget_act_bits
            model = retarget_act_bits(model, args.act_bits)
            print(f"[serve] retargeted activations to "
                  f"{'fp' if args.act_bits == 0 else f'{args.act_bits}-bit'}")
        print(f"[serve] loaded artifact {args.artifact}: "
              f"{model.plan.describe()}")
    else:
        model = (_build_encoder_model(args) if args.mode == "encoder"
                 else _build_model(args))
        if args.export:
            path = model.save(args.export)
            print(f"[serve] exported artifact to {path}")
    if args.mode == "encoder" and model.plan.mode != "encoder":
        p.error(f"--mode encoder needs a mode='encoder' artifact; "
                f"{args.artifact or 'the built model'} is "
                f"mode={model.plan.mode!r}")

    cfg = model.plan.cfg
    kv_budget = (int(args.kv_budget_mb * (1 << 20))
                 if args.kv_budget_mb is not None else None)
    if args.replicas > 1:
        from ..serving import ReplicaSet
        eng = ReplicaSet(model, replicas=args.replicas, slots=args.slots,
                         max_len=args.max_len, max_queue=args.max_queue,
                         kv_budget_bytes=kv_budget, warmup=args.warmup)
        print(f"[serve] replica set: {args.replicas} engines, "
              f"{args.slots} slots each")
    else:
        eng = ServingEngine(model, slots=args.slots, max_len=args.max_len,
                            max_queue=args.max_queue,
                            kv_budget_bytes=kv_budget, warmup=args.warmup)
    if model.plan.mode == "encoder":
        return _serve_encoder_burst(args, eng, cfg)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed, n=args.n)
    stop = (frozenset(int(t) for t in args.stop.split(","))
            if args.stop else frozenset())
    on_token = ((lambda rid, tok: print(f"[stream] rid={rid} tok={tok}"))
                if args.stream else None)

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size,
                          args.shared_prefix).astype(np.int32)
    t0 = time.time()
    steps = 0
    for _ in range(args.requests):
        plen = int(rng.integers(4, 12))
        tail = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        req = GenerationRequest(
            prompt=np.concatenate([shared, tail]),
            max_new_tokens=8, sampling=sampling, stop_tokens=stop)
        while True:
            try:
                eng.submit(req, on_token=on_token)
                break
            except QueueFullError:       # backpressure: drain a round, retry
                eng.engine_step()
                steps += 1
    steps += eng.run_until_drained()
    dt = time.time() - t0
    finished = eng.pop_done()
    total_tokens = sum(len(r.out) for r in finished)
    stopped = sum(r.finish_reason == "stop" for r in finished)
    print(f"[serve] {len(finished)} requests, {total_tokens} tokens, "
          f"{steps} engine steps, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{stopped} stop-token exits)")
    print(f"[serve] {eng.metrics.report()}")


def _serve_encoder_burst(args, eng, cfg):
    """Synthetic prefill-only burst (DESIGN.md §14): submit EncodeRequests,
    drain, report — the encoder-mode analogue of the generation burst."""
    rng = np.random.default_rng(0)
    t0 = time.time()
    steps = 0
    handles = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 17))
        toks = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        req = EncodeRequest(tokens=toks, task=args.task)
        while True:
            try:
                handles.append(eng.submit_encode(req))
                break
            except QueueFullError:       # backpressure: drain a round, retry
                eng.engine_step()
                steps += 1
    steps += eng.run_until_drained()
    dt = time.time() - t0
    finished = eng.pop_done()
    done = sum(r.finish_reason == "done" for r in finished)
    total = sum(len(r.tokens) for r in finished)
    print(f"[serve] encoder burst: {len(finished)} requests ({done} done), "
          f"{total} input tokens, {steps} engine steps, {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, task={args.task})")
    print(f"[serve] {eng.metrics.report()}")


def _main_tenants(args):
    """--tenant NAME=DIR...: host every artifact in one MultiTenantEngine
    and round-robin a synthetic burst across tenants (encode traffic for
    encoder artifacts, generation otherwise)."""
    from ..deploy import DeployedModel

    pairs = []
    for spec in args.tenant:
        name, sep, path = spec.partition("=")
        if not sep or not name or not path:
            raise SystemExit(f"--tenant expects NAME=DIR, got {spec!r}")
        pairs.append((name, path))

    mt = MultiTenantEngine()
    for name, path in pairs:
        model = DeployedModel.load(path)
        mt.add_tenant(name, model, slots=args.slots, max_len=args.max_len,
                      max_queue=args.max_queue)
        print(f"[serve] tenant {name!r}: {model.plan.describe()}")

    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    rng = np.random.default_rng(0)
    t0 = time.time()
    steps = 0
    for i in range(args.requests):
        name = pairs[i % len(pairs)][0]
        t = mt.tenants[name]
        vocab = t.engine.cfg.vocab_size
        plen = int(rng.integers(4, 12))
        toks = rng.integers(1, vocab, plen).astype(np.int32)
        while True:
            try:
                if t.engine.mode == "encoder":
                    mt.submit_encode(EncodeRequest(tokens=toks,
                                                   task=args.task),
                                     tenant=name)
                else:
                    mt.submit(GenerationRequest(prompt=toks,
                                                max_new_tokens=8,
                                                sampling=sampling),
                              tenant=name)
                break
            except QueueFullError:       # backpressure: drain a round, retry
                mt.engine_step()
                steps += 1
    steps += mt.run_until_drained()
    dt = time.time() - t0
    finished = mt.pop_done()
    print(f"[serve] multi-tenant burst: {len(finished)} requests over "
          f"{len(pairs)} tenants, {steps} engine steps, {dt:.2f}s")
    print(f"[serve] {mt.metrics.report()}")


if __name__ == "__main__":
    main()
