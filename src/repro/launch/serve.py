"""Thin CLI shim over the serving subsystem (repro/serving — DESIGN.md §7).

The engine itself lives in ``repro.serving``: scheduler (queue + slot table),
kv_cache (per-slot cursors), engine (prefill/decode step loop), metrics
(latency/throughput). ``Request`` and ``ServingEngine`` stay importable from
here for backward compatibility.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..serving import Request, ServingEngine  # noqa: F401  (compat re-export)


def main(argv=None):
    from ..configs import get_config, reduced
    from ..core.policy import QuantPolicy
    from ..core.qat import calibrate_weight_scales, default_bits_fn, \
        deploy_params
    from ..models import api

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--int4-last-k", type=int, default=-1)
    p.add_argument("--prefill-mode", default="auto",
                   choices=["auto", "chunked", "token"])
    p.add_argument("--use-pallas", action="store_true",
                   help="route matmuls through the int4/int8 Pallas kernels "
                        "(fused decode epilogue; interpret mode off-TPU)")
    p.add_argument("--kv-bits", type=int, default=16, choices=[16, 8, 4],
                   help="serving KV-cache precision (DESIGN.md §8): 16 keeps "
                        "fp rows; 8/4 store packed codes + per-(token, head) "
                        "scales and decode via the fused Pallas "
                        "decode-attention kernel when --use-pallas is set")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_units = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    k4 = args.int4_last_k if args.int4_last_k >= 0 else n_units // 2
    policy = QuantPolicy(num_layers=n_units, mode="int", last_k_int4=k4)
    segments = api.segments_for(cfg, policy, use_pallas=args.use_pallas,
                                fuse_epilogue=args.use_pallas)

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    params = calibrate_weight_scales(params, default_bits_fn(cfg, policy))
    params_int = deploy_params(params, cfg, segments)

    eng = ServingEngine(params_int, cfg, segments, slots=args.slots,
                        max_len=128, prefill_mode=args.prefill_mode,
                        kv_bits=args.kv_bits)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(prompt=rng.integers(
            1, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=8))
    steps = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in eng.done)
    print(f"[serve] {len(eng.done)} requests, {total_tokens} tokens, "
          f"{steps} engine steps, {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] {eng.metrics.report()}")


if __name__ == "__main__":
    main()
