"""Post-SPMD HLO text analysis: per-device FLOPs / bytes / collective bytes.

Why not just ``compiled.cost_analysis()``? XLA's analysis counts a while-loop
BODY ONCE, ignoring the trip count — and this framework scans over layers, so
80-layer models would report ~1 layer of FLOPs. We therefore walk the HLO
call graph ourselves:

  * computations are parsed from ``compiled.as_text()`` (shapes at def sites),
  * ``while`` ops carry ``known_trip_count`` in backend_config -> multiplier,
  * fusions/calls propagate the enclosing multiplier,
  * dot FLOPs = 2 x numel(result) x contraction extent (batch dims handled
    by the result shape), scaled by the multiplier product,
  * collective bytes = operand bytes per participating device, scaled (the
    assignment's convention); all-reduce additionally x2 (reduce+broadcast
    phases of ring/tree algorithms),
  * HBM bytes = sum over top-level fusion/dot/copy/collective ops of
    (operand + result bytes) — the standard "every fusion reads and writes
    HBM once" roofline approximation.

All numbers are PER DEVICE (the compiled module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # raw text after the opcode's '('


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    defs: dict         # op name -> type string


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if mc and "{" in line:
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, type_str, opcode, rest = mo.groups()
            cur.ops.append(Op(name, type_str, opcode, rest))
            cur.defs[name] = type_str
    return comps


def _operand_names(rest: str) -> list[str]:
    # operands are leading %name tokens before attribute list
    head = rest.split("),", 1)[0]
    return re.findall(r"%([\w.\-]+)", head)


def _called(rest: str) -> list[tuple[str, float]]:
    """(computation, extra multiplier) called by this op line."""
    out = []
    m = re.search(r'body=%?([\w.\-]+)', rest)
    if m:
        trip = 1.0
        t = re.search(r'known_trip_count[":{]+n[": ]+(\d+)', rest)
        if t:
            trip = float(t.group(1))
        out.append((m.group(1), trip))
        c = re.search(r'condition=%?([\w.\-]+)', rest)
        if c:
            out.append((c.group(1), trip))
        return out
    m = re.search(r'calls=%?([\w.\-]+)', rest)
    if m:
        out.append((m.group(1), 1.0))
    m = re.search(r'branch_computations=\{([^}]*)\}', rest)
    if m:
        for b in re.findall(r"%?([\w.\-]+)", m.group(1)):
            out.append((b, 1.0))  # conditional: count every branch once
    return out


def _fusion_effective_bytes(op: "Op", comp: "Computation", comps: dict) -> int:
    """HBM bytes actually moved by one fusion execution, slice-aware:

    * a param consumed only by dynamic-slice reads the SLICE, not the buffer
      (stacked layer weights indexed by the scan counter);
    * a param consumed only as the dynamic-update-slice TARGET is aliased
      in-place — the write is the UPDATE's bytes, not buffer + result
      (scan's per-step stacking of carries/grads);
    * everything else counts at face value.
    """
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    opnds = _operand_names(op.rest)
    if not m or m.group(1) not in comps:
        return _shape_bytes(op.type_str) + sum(
            _shape_bytes(comp.defs.get(o, "")) for o in opnds)
    fused = comps[m.group(1)]
    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape")
    param_idx: dict[str, int] = {}
    uses: dict[str, list] = {}
    op_by_name = {fop.name: fop for fop in fused.ops}
    for fop in fused.ops:
        if fop.opcode == "parameter":
            pm = re.match(r"\s*(\d+)", fop.rest)
            if pm:
                param_idx[fop.name] = int(pm.group(1))
        for o in _operand_names(fop.rest):
            uses.setdefault(o, []).append(fop)

    def resolve(name: str) -> str:
        """walk transparent-op chains back to their source op name."""
        seen = 0
        while name in op_by_name and op_by_name[name].opcode in _TRANSPARENT \
                and seen < 20:
            ops_ = _operand_names(op_by_name[name].rest)
            if not ops_:
                break
            name = ops_[0]
            seen += 1
        return name

    in_place_params: set[str] = set()
    dus_update_bytes = 0
    for fop in fused.ops:
        if fop.opcode == "dynamic-update-slice":
            o = _operand_names(fop.rest)
            if o and resolve(o[0]) in param_idx:
                in_place_params.add(resolve(o[0]))
            if len(o) >= 2:
                dus_update_bytes += _shape_bytes(fused.defs.get(o[1], ""))

    def sink_kinds(name: str, depth=0) -> set:
        """opcodes that ultimately consume ``name`` (through transparent ops)."""
        out: set = set()
        if depth > 20:
            return out
        for c in uses.get(name, []):
            if c.opcode in _TRANSPARENT:
                out |= sink_kinds(c.name, depth + 1)
            else:
                out.add(c.opcode)
        return out

    total = 0
    for pname, idx in param_idx.items():
        if idx >= len(opnds):
            continue
        if pname in in_place_params:
            continue                       # aliased in-place buffer
        kinds = sink_kinds(pname)
        if kinds and kinds <= {"dynamic-slice"}:
            slices = [c for c in fused.ops if c.opcode == "dynamic-slice"
                      and resolve(_operand_names(c.rest)[0]) == pname]
            total += sum(_shape_bytes(c.type_str) for c in slices)
        else:
            total += _shape_bytes(comp.defs.get(opnds[idx], ""))
    if in_place_params:
        total += 2 * dus_update_bytes      # read update + write slice
    else:
        total += _shape_bytes(op.type_str)
    return total


def _def_op(comp: "Computation", name: str) -> Optional["Op"]:
    for o in comp.ops:
        if o.name == name:
            return o
    return None


def analyze(hlo: str, entry: Optional[str] = None) -> dict:
    comps = parse_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))

    # accumulate multipliers per computation via DFS (call graph is a DAG)
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            for callee, extra in _called(op.rest):
                if callee in comps:
                    mult[callee] = mult.get(callee, 0.0) + mult[cname] * extra
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    flops = 0.0
    int_flops = 0.0     # int8 MXU path (s32 accumulators) — 2x bf16 peak
    coll_bytes: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    hbm_bytes = 0.0
    dots = []
    colls = []
    hbm_items = []
    hbm_by_mult: dict[float, float] = {}

    def _hbm(amount, op, cname, m):
        nonlocal hbm_bytes
        hbm_bytes += amount
        hbm_by_mult[m] = hbm_by_mult.get(m, 0.0) + amount
        hbm_items.append((amount, f"{op.opcode}:{op.name}", cname, m))
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # consumers map: converts feeding ONLY dynamic-slices are charged at
        # the slice size (the CPU backend hoists bf16->f32 converts of whole
        # stacked caches above the per-layer slice; TPU sinks them below).
        consumers: dict[str, list] = {}
        for op in comp.ops:
            for o in _operand_names(op.rest):
                consumers.setdefault(o, []).append(op)
        for op in comp.ops:
            if op.opcode == "dot":
                opnds = _operand_names(op.rest)
                lhs_t = comp.defs.get(opnds[0], "") if opnds else ""
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                contr = 1
                if cd and lhs_t:
                    dims_m = _SHAPE_RE.search(lhs_t)
                    if dims_m:
                        dims = [int(x) for x in dims_m.group(2).split(",") if x]
                        for ci in cd.group(1).split(","):
                            if ci:
                                contr *= dims[int(ci)]
                f = 2.0 * _shape_elems(op.type_str) * contr * m
                flops += f
                is_int = op.type_str.strip().startswith(("s32", "s16", "s8",
                                                         "u32"))
                if is_int:
                    int_flops += f
                dots.append({"name": op.name, "flops": f, "mult": m,
                             "out": op.type_str.strip()})
                # TPU dtype model: the CPU backend upcasts bf16 matmuls to
                # f32; on the TPU target float matmul operands stream at
                # 2 B/elem (bf16, f32 accumulation in VREGs). Int dots keep
                # their integer widths.
                b = 0
                for o in opnds:
                    ts = comp.defs.get(o, "")
                    ob = _shape_bytes(ts)
                    if not is_int and ts.strip().startswith(("f32", "f64")):
                        ob //= 2
                    b += ob
                b += (_shape_bytes(op.type_str) // (1 if is_int else 2))
                _hbm(m * b, op, cname, m)
            elif op.opcode in _COLLECTIVES:
                opnds = _operand_names(op.rest)
                b = sum(_shape_bytes(comp.defs.get(o, "")) for o in opnds)
                factor = 2.0 if op.opcode == "all-reduce" else 1.0
                coll_bytes[op.opcode] += b * factor * m
                colls.append({"op": op.opcode, "bytes": b, "mult": m,
                              "name": op.name})
                _hbm(m * (_shape_bytes(op.type_str) + b), op, cname, m)
            elif op.opcode == "dynamic-update-slice":
                # in-place update: traffic = read + write of the UPDATE slice
                # (counting the full buffer would charge stacked-grad scatter
                # inside scan bodies L x full-stack bytes — wrong).
                opnds = _operand_names(op.rest)
                if len(opnds) >= 2:
                    _hbm(m * 2 * _shape_bytes(comp.defs.get(opnds[1], "")),
                         op, cname, m)
            elif op.opcode in ("dynamic-slice", "gather"):
                # reads only the slice it produces
                _hbm(m * 2 * _shape_bytes(op.type_str), op, cname, m)
            elif op.opcode == "fusion":
                _hbm(m * _fusion_effective_bytes(op, comp, comps), op, cname, m)
            elif op.opcode in ("copy", "custom-call", "reduce", "convert",
                               "transpose", "concatenate", "sort", "scatter"):
                opnds = _operand_names(op.rest)
                cons = consumers.get(op.name, [])
                if op.opcode in ("convert", "copy", "transpose") and cons and \
                        all(c.opcode == "dynamic-slice" for c in cons):
                    _hbm(m * 2 * sum(_shape_bytes(c.type_str) for c in cons),
                         op, cname, m)
                elif op.opcode in ("convert", "copy") and cons and all(
                        c.opcode == "dynamic-update-slice"
                        and _operand_names(c.rest)[:1] == [op.name]
                        for c in cons):
                    pass  # dtype-wrapper around an in-place cache update:
                    # the CPU backend emulates bf16 by f32-converting the
                    # whole buffer; TPU aliases it. DUS itself is charged.
                elif op.opcode in ("convert", "copy") and any(
                        comp.defs.get(o, "") and src.opcode ==
                        "dynamic-update-slice"
                        for o in _operand_names(op.rest)[:1]
                        for src in [_def_op(comp, o)] if src is not None):
                    pass  # convert-back of the DUS result (same pattern)
                elif op.opcode == "convert" and cons and all(
                        c.opcode == "dot" for c in cons):
                    pass  # CPU-only f32 upcast feeding a matmul: the TPU
                    # target runs the dot in bf16; read charged at the dot
                else:
                    _hbm(m * (_shape_bytes(op.type_str) + sum(
                        _shape_bytes(comp.defs.get(o, "")) for o in opnds)),
                        op, cname, m)
    return {
        "flops": flops,
        "int_flops": int_flops,
        "float_flops": flops - int_flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_bytes,
        "collective_bytes_total": sum(coll_bytes.values()),
        "top_dots": sorted(dots, key=lambda d: -d["flops"])[:12],
        "top_hbm": [{"bytes": b, "op": o, "comp": c, "mult": mm}
                    for b, o, c, mm in sorted(hbm_items, reverse=True)[:12]],
        "hbm_by_mult": {str(int(k)): v for k, v in
                        sorted(hbm_by_mult.items())},
        "top_collectives": sorted(colls, key=lambda c: -c["bytes"] * c["mult"])[:12],
        "n_computations": len(comps),
    }
