"""Production mesh builders (dry-run target: TPU v5e, 256 chips/pod).

FUNCTIONS, not module constants: importing this module never touches jax
device state (jax locks the device count on first backend init).

``make_mesh_for_devices`` returns a :class:`MeshLayout` — the mesh plus the
RESOLVED (data, model) split that produced it. Callers used to get a bare
mesh with the model-parallel degree silently halved whenever it didn't
divide the device count; the resolved shape is now part of the return value,
and an explicitly requested degree that doesn't fit raises instead of
degrading (degrading stays opt-in for the elastic-restart path, which
documents "preserved when possible, else halved").
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshLayout:
    """A (data, model) device mesh plus the shape that was actually built.

    ``requested_model`` is the caller's ask (0 = auto); ``degraded`` is True
    when an explicit request was halved down to a divisor (only possible
    with ``allow_degrade=True``).
    """

    mesh: jax.sharding.Mesh
    data: int
    model: int
    requested_model: int
    degraded: bool

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 0, *,
                          allow_degrade: bool = False) -> MeshLayout:
    """Elastic variant: whatever devices are alive -> (data, model) layout.

    model_parallel <= 0 auto-picks (min(16, n) halved to the nearest
    divisor). An EXPLICIT degree that doesn't divide ``n_devices`` raises a
    ValueError naming both numbers — unless ``allow_degrade=True``
    (launch/elastic.py's restart path, where "preserved if possible, else
    halved" is the documented contract); the halving is then recorded in
    ``MeshLayout.degraded`` instead of happening silently.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    requested = model_parallel
    if model_parallel <= 0:
        model_parallel = min(16, n_devices)
        while n_devices % model_parallel:
            model_parallel //= 2
    elif n_devices % model_parallel:
        if not allow_degrade:
            raise ValueError(
                f"model_parallel={model_parallel} does not divide "
                f"n_devices={n_devices}; pick a divisor, or pass "
                f"allow_degrade=True to halve to the nearest one")
        while n_devices % model_parallel:
            model_parallel //= 2
    mesh = jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"))
    return MeshLayout(mesh=mesh, data=n_devices // model_parallel,
                      model=model_parallel, requested_model=requested,
                      degraded=requested > 0 and model_parallel != requested)


def make_tp_mesh(tp: int) -> jax.sharding.Mesh:
    """A 1-axis ("model",) mesh over the first ``tp`` devices — the serving
    tensor-parallel layout (DESIGN.md §16). Data parallelism in serving is
    process-level (ReplicaSet), so the serving mesh carries no data axis."""
    devs = jax.devices()
    if len(devs) < tp:
        raise RuntimeError(
            f"tp={tp} needs {tp} devices, host has {len(devs)} "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count=N simulates "
            f"more on CPU)")
    return jax.sharding.Mesh(np.asarray(devs[:tp]), ("model",))
