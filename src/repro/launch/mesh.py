"""Production mesh builders (dry-run target: TPU v5e, 256 chips/pod).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 0):
    """Elastic variant: whatever devices are alive -> (data, model) mesh.

    Used by the restart path when a pod comes back with fewer hosts
    (launch/elastic.py): model parallelism is preserved if possible, the
    data axis absorbs the change.
    """
    if model_parallel <= 0:
        model_parallel = min(16, n_devices)
    while n_devices % model_parallel:
        model_parallel //= 2
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"))
