"""Fault-tolerant QAT training driver.

Flow (paper §4/§5): finetune fp teacher (or load) -> calibrate (weight scales
abs-max, activation scales percentile) -> QAT with LSQ-MSE scale gradients and
MINI distillation -> deploy int4/int8.

Fault tolerance: atomic checkpoints every --ckpt-every steps and on SIGTERM;
restart auto-resumes from the latest complete step (crash mid-save can never
corrupt it — checkpoint/manager.py). A straggler watchdog flags steps slower
than k x EMA (on real pods this feeds the controller's restart policy).

Runs single-host on any device count (CPU smoke: 1 device); the same step
function jit-compiles under the production mesh in dryrun.py.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp


def build_train_step(plan, hparams, teacher=None, teacher_plan=None):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics).

    ``plan``/``teacher_plan`` are ``repro.deploy.ExecutionPlan``s (student
    QAT plan and fp teacher plan)."""
    from ..core.distill import (combine_losses, hidden_state_loss,
                                minilm_losses, output_loss)
    from ..models import api
    from ..models.transformer import lm_loss
    from ..optim import adam_update, linear_warmup_decay

    cfg = plan.cfg
    teacher_cfg = teacher_plan.cfg if teacher_plan is not None else None
    sched = linear_warmup_decay(hparams.total_steps, hparams.warmup_frac)
    lr_by_group = {"weights": hparams.lr_weights,
                   "act_scale": hparams.lr_act_scale,
                   "weight_scale": hparams.lr_weight_scale}
    distill = teacher is not None

    def loss_fn(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, taps_s, aux = api.forward(params, plan,
                                             want_taps=distill, **inputs)
        l_train = lm_loss(logits, batch["labels"]) + aux
        if not distill:
            return l_train, {"loss/train": l_train}
        t_logits, _, taps_t, _ = api.forward(teacher, teacher_plan,
                                             want_taps=True, **inputs)
        l_out = output_loss(logits, jax.lax.stop_gradient(t_logits))
        taps_t = jax.lax.stop_gradient(taps_t)
        if taps_s is not None and "q" in (taps_s or {}):
            R = min(cfg.num_heads, teacher_cfg.num_heads)
            l_attn, l_val = minilm_losses(taps_s, taps_t, R)
        else:  # attention-free family: hidden-state distill (DESIGN.md §5)
            l_attn = hidden_state_loss(taps_s["hidden"], taps_t["hidden"])
            l_val = jnp.zeros(())
        total, parts = combine_losses(l_train, l_out, l_attn, l_val,
                                      hparams.alpha, hparams.beta)
        return total, parts

    def train_step(params, opt, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt = adam_update(params, grads, opt,
                                  lr_by_group=lr_by_group, schedule_fn=sched,
                                  b1=hparams.adam_b1, b2=hparams.adam_b2,
                                  eps=hparams.adam_eps,
                                  grad_clip=hparams.grad_clip)
        return params, opt, parts

    return train_step


class StragglerWatchdog:
    """Flags steps slower than ``factor`` x EMA of recent step times."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor, self.alpha, self.ema = factor, alpha, None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.flagged.append((step, dt))
        self.ema = dt if self.ema is None else (
            (1 - self.alpha) * self.ema + self.alpha * dt)
        return slow


def run_training(cfg, policy, hparams, data_iter, *, ckpt_dir: str,
                 ckpt_every: int = 50, distill_teacher=None, teacher_cfg=None,
                 log_every: int = 10, max_steps=None, on_step=None):
    """The loop: resume -> step -> checkpoint; SIGTERM-safe."""
    from ..checkpoint import CheckpointManager
    from ..deploy import ExecutionPlan
    from ..models import api
    from ..optim import adam_init

    plan = ExecutionPlan.build(cfg, policy)
    teacher_plan = (ExecutionPlan.build(teacher_cfg, None)
                    if teacher_cfg is not None else None)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    opt = adam_init(params)
    mgr = CheckpointManager(ckpt_dir)
    state = {"params": params, "opt": opt}
    restored, step0 = mgr.restore(state)
    if restored is not None:
        state = restored
        print(f"[train] resumed from step {step0}", flush=True)
    step0 = step0 or 0

    step_fn = jax.jit(build_train_step(plan, hparams,
                                       teacher=distill_teacher,
                                       teacher_plan=teacher_plan))
    stop = {"now": False}

    def _sigterm(signum, frame):  # checkpoint-and-exit on preemption
        stop["now"] = True
    old = signal.signal(signal.SIGTERM, _sigterm)

    watchdog = StragglerWatchdog()
    total = max_steps or hparams.total_steps
    metrics = {}
    try:
        for step in range(step0, total):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
            params, opt, metrics = step_fn(state["params"], state["opt"],
                                           batch)
            state = {"params": params, "opt": opt}
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(ema {watchdog.ema:.2f}s)", flush=True)
            if log_every and step % log_every == 0:
                ms = {k: float(v) for k, v in metrics.items()}
                print(f"[train] step {step} {ms} ({dt:.2f}s)", flush=True)
            if on_step is not None:
                on_step(step, state, metrics)
            if ckpt_every and (step + 1) % ckpt_every == 0 or stop["now"]:
                mgr.save(step + 1, state,
                         {k: float(v) for k, v in metrics.items()})
            if stop["now"]:
                print("[train] SIGTERM: checkpointed, exiting", flush=True)
                break
    finally:
        signal.signal(signal.SIGTERM, old)
    return state, {k: float(v) for k, v in metrics.items()}


def main(argv=None):
    from ..configs import TrainHParams, get_config, reduced
    from ..core.policy import QuantPolicy
    from ..data import lm_batches

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--reduced", action="store_true",
                   help="smoke-size model (CPU)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--int4-last-k", type=int, default=-1)
    p.add_argument("--grad-mode", default="mse", choices=["mse", "ste"])
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n_units = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    k4 = args.int4_last_k if args.int4_last_k >= 0 else n_units // 2
    policy = QuantPolicy(num_layers=n_units, mode="fake", last_k_int4=k4,
                         grad_mode=args.grad_mode)
    hp = TrainHParams(total_steps=args.steps)
    data = lm_batches(cfg.vocab_size, args.seq, args.batch)
    state, metrics = run_training(cfg, policy, hp, iter(data),
                                  ckpt_dir=args.ckpt_dir,
                                  max_steps=args.steps)
    print("[train] done", metrics)


if __name__ == "__main__":
    main()
