"""Deterministic synthetic data pipelines (offline container — DESIGN.md §6).

Tasks have LEARNABLE structure (not pure noise) so QAT/distill quality
benchmarks are meaningful:

* ``SyntheticLM``: order-2 Markov token stream from a seeded random transition
  table with temperature — a model must learn real conditional structure.
* ``SyntheticClassification``: GLUE-like sentence classification; the label is
  a seeded linear readout of bag-of-token-embedding features + label noise.

Both shard by (host_index, num_hosts) and prefetch with a background thread,
the same interface a real tokenized-corpus loader would expose.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 32          # out-degree of the Markov table
    host_index: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        self._table = rng.integers(0, V, size=(min(V, 4096), self.branching))

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, step, self.host_index, 0xA11CE))
        B, S = self.batch_size // self.num_hosts, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._table.shape[0], size=B)
        choices = rng.integers(0, self.branching, size=(B, S))
        for t in range(S):
            toks[:, t + 1] = self._table[toks[:, t] % self._table.shape[0],
                                         choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class SyntheticClassification:
    """GLUE-like task: y = argmax(W_cls @ mean(embed[tokens]) + noise)."""
    vocab_size: int
    seq_len: int
    batch_size: int
    num_classes: int = 2
    seed: int = 0
    label_noise: float = 0.05
    host_index: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._embed = rng.standard_normal((self.vocab_size, 16)).astype(np.float32)
        self._readout = rng.standard_normal((16, self.num_classes)).astype(np.float32)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step, self.host_index, 0xBEEF))
        B = self.batch_size // self.num_hosts
        toks = rng.integers(1, self.vocab_size, size=(B, self.seq_len)).astype(np.int32)
        toks[:, 0] = 0  # [CLS]
        feats = self._embed[toks].mean(axis=1)
        logits = feats @ self._readout
        labels = logits.argmax(-1)
        flip = rng.random(B) < self.label_noise
        labels = np.where(flip, rng.integers(0, self.num_classes, B), labels)
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (host-side overlap with device compute)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop:
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True


def lm_batches(vocab, seq, batch, seed=0, prefetch=True, **kw):
    it = iter(SyntheticLM(vocab, seq, batch, seed=seed, **kw))
    return Prefetcher(it) if prefetch else it


def classification_batches(vocab, seq, batch, num_classes=2, seed=0,
                           prefetch=False, **kw):
    it = iter(SyntheticClassification(vocab, seq, batch, num_classes,
                                      seed=seed, **kw))
    return Prefetcher(it) if prefetch else it
