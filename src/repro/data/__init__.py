from .synthetic import (SyntheticLM, SyntheticClassification,  # noqa: F401
                        lm_batches, classification_batches)
