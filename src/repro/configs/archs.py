"""The 10 assigned architecture configs (exact dims from the assignment).

[source; verified-tier] noted per entry. Modality frontends for [audio]/[vlm]
are stubs — ``input_specs`` provides precomputed frame/patch embeddings.
"""
from __future__ import annotations

from .base import ModelConfig

ARCHS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- dense -----------------------------------------------------------------
# [hf:stabilityai/stablelm-2-1_6b; unverified]
_reg(ModelConfig(
    name="stablelm-3b", family="dense", num_layers=32, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=6912, vocab_size=50304,
    act="swiglu", norm="ln", qkv_bias=False))

# GQA [arXiv:2403.17297; hf]
_reg(ModelConfig(
    name="internlm2-20b", family="dense", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=92544,
    act="swiglu", rope_theta=1e6))

# QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]
_reg(ModelConfig(
    name="qwen1.5-110b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=49152, vocab_size=152064,
    qkv_bias=True, act="swiglu", rope_theta=1e6))

# GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]
_reg(ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=27648, vocab_size=152064,
    qkv_bias=True, act="swiglu", rope_theta=1e6))

# --- ssm -------------------------------------------------------------------
# sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]. d_ff=0: no std FFN.
_reg(ModelConfig(
    name="xlstm-1.3b", family="xlstm", num_layers=48, d_model=2048,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    slstm_every=8, ssm_expand=2, ssm_chunk=256, rope=False))

# --- audio enc-dec ---------------------------------------------------------
# enc-dec, multimodal [arXiv:2308.11596; hf]; frontend stubbed (frame embeds).
_reg(ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=24,
    enc_layers=12, dec_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=256206, act="gelu", norm="ln",
    input_kind="embeds"))

# --- vlm -------------------------------------------------------------------
# anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified];
# Mistral-7B backbone; patch embeddings stubbed (anyres 2x576 grid).
_reg(ModelConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    act="swiglu", rope_theta=1e6, num_patches=1152,
    input_kind="tokens+patches"))

# --- moe -------------------------------------------------------------------
# 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
_reg(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
    num_experts=60, top_k=4, expert_d_ff=1408, shared_expert_d_ff=5632,
    qkv_bias=True, act="swiglu"))

# 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
_reg(ModelConfig(
    name="granite-moe-3b-a800m", family="moe", num_layers=32, d_model=1536,
    num_heads=24, num_kv_heads=8, d_ff=512, vocab_size=49155,
    num_experts=40, top_k=8, expert_d_ff=512, shared_expert_d_ff=0,
    act="swiglu"))

# --- hybrid ----------------------------------------------------------------
# Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
_reg(ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6))

# --- the paper's own models (reproduction) ----------------------------------
# TinyBERT4 student (Jiao et al. 2019): L4 d312 h12 dff1200
_reg(ModelConfig(
    name="tinybert4", family="bert", num_layers=4, d_model=312,
    num_heads=12, num_kv_heads=12, d_ff=1200, vocab_size=30522,
    qkv_bias=True, out_bias=True, norm="ln", act="gelu", rope=False,
    causal=False, learned_pos=True, dtype="float32", remat=False))

# BERT-base teacher shape (Devlin et al. 2018)
_reg(ModelConfig(
    name="bert-base", family="bert", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=30522,
    qkv_bias=True, out_bias=True, norm="ln", act="gelu", rope=False,
    causal=False, learned_pos=True, dtype="float32", remat=False))

ASSIGNED = [
    "stablelm-3b", "internlm2-20b", "qwen1.5-110b", "qwen2.5-32b",
    "xlstm-1.3b", "seamless-m4t-medium", "llava-next-mistral-7b",
    "qwen2-moe-a2.7b", "granite-moe-3b-a800m", "zamba2-2.7b",
]
