"""Config system: model/shape/quant/train/mesh dataclasses + input_specs.

Every assigned architecture is a ``ModelConfig`` in its own module; the
registry in ``configs/__init__`` resolves ``--arch <id>`` and provides the
reduced smoke-test variant of each config.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | xlstm | hybrid | encdec | vlm | bert
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads
    qkv_bias: bool = False
    out_bias: bool = False
    norm: str = "rms"           # rms | ln
    act: str = "swiglu"         # swiglu | gelu  (gelu => non-gated 2-matmul FFN)
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    tie_embeddings: bool = False
    learned_pos: bool = False   # BERT-style positional embeddings
    # MoE
    num_experts: int = 0
    top_k: int = 0
    shared_expert_d_ff: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    moe_impl: str = "dense"     # dense (one-hot einsum) | sorted (gather)
    router_aux_coef: float = 0.001
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0         # zamba2: shared attention block every k-th layer
    slstm_every: int = 0        # xlstm: sLSTM block every k-th layer (rest mLSTM)
    # VLM
    num_patches: int = 0
    input_kind: str = "tokens"  # tokens | embeds | tokens+patches
    # execution
    attn_chunk_threshold: int = 2048   # seqs longer than this use chunked
    attn_chunk: int = 1024             # (flash-style) attention
    attn_seq_shard: bool = False       # context-parallel chunked attention
    kv_bits: int = 16                  # serving KV cache: 16 (fp) | 8 | 4
    dp_axes: tuple = ("data",)         # mesh DP axis names (for constraints)
    fused_proj: bool = False           # fused QKV + gate-up FFN matmuls
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a TP-shardable multiple (logits for the
        padding rows are masked to -inf before any softmax/loss)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def compute_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "ModelConfig":
        """Inverse of ``dataclasses.asdict`` after a JSON round trip (the
        DeployedModel artifact meta — DESIGN.md §9): JSON turns the
        ``dp_axes`` tuple into a list. Unknown keys are dropped so
        artifacts written by a NEWER build (which may add cfg fields
        without bumping the artifact version) still load."""
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        d["dp_axes"] = tuple(d.get("dp_axes", ("data",)))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Full-attention archs skip long_500k (DESIGN.md §5); SSM/hybrid run it.
SUBQUADRATIC_FAMILIES = ("xlstm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "full-attention arch: 500k decode skipped (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cd = cfg.compute_dtype
    if shape.kind == "train":
        specs = {}
        if cfg.input_kind == "embeds":        # audio frontend stub: frame embeddings
            specs["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cd)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        elif cfg.input_kind == "tokens+patches":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), cd)
            specs["patch_mask"] = jax.ShapeDtypeStruct((B, S), jnp.bool_)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        if cfg.input_kind == "embeds":
            specs["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cd)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        elif cfg.input_kind == "tokens+patches":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), cd)
            specs["patch_mask"] = jax.ShapeDtypeStruct((B, S), jnp.bool_)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": None}  # cache specs are family-specific; see launch.dryrun


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    """QAT hyperparameters (paper §5.2)."""
    lr_weights: float = 1e-5
    lr_act_scale: float = 0.01
    lr_weight_scale: float = 0.001
    warmup_frac: float = 0.10
    total_steps: int = 1000
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    grad_clip: float = 1.0
    alpha: float = 10.0         # output-distill weight
    beta: float = 1.0           # MINI-distill weight
    microbatch: int = 0         # 0 = no grad accumulation
    grad_compression: bool = False
