"""Architecture registry: ``--arch <id>`` lookup + reduced smoke variants.

Exact assigned configs (sources in each module's docstring / the assignment
table). ``reduced(cfg)`` shrinks a config to a CPU-runnable smoke variant of
the same family (same block wiring, tiny dims).
"""
from __future__ import annotations

import dataclasses

from .base import (SHAPES, ModelConfig, ShapeSpec, TrainHParams, input_specs,
                   shape_applicable)

from . import archs as _archs

ARCHS: dict[str, ModelConfig] = _archs.ARCHS


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family/wiring, tiny dims, CPU-friendly."""
    kw = dict(
        num_layers=4, d_model=64, num_heads=4, head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", remat=False,
        attn_chunk_threshold=64, attn_chunk=32, ssm_chunk=8,
        moe_group_size=16,
    )
    kw["num_kv_heads"] = min(cfg.num_kv_heads, 4) if cfg.num_kv_heads < cfg.num_heads else 4
    if cfg.family == "moe":
        kw.update(num_experts=8, top_k=min(cfg.top_k, 4), expert_d_ff=32,
                  shared_expert_d_ff=64 if cfg.shared_expert_d_ff else 0)
    if cfg.family == "xlstm":
        kw.update(num_layers=4, slstm_every=2, num_heads=2, num_kv_heads=2,
                  ssm_expand=2, d_ff=0)
    if cfg.family == "hybrid":
        kw.update(num_layers=4, attn_every=2, ssm_state=8, ssm_head_dim=16,
                  ssm_expand=2, num_kv_heads=4)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, num_layers=2)
    if cfg.family == "vlm":
        kw.update(num_patches=8)
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "TrainHParams",
           "get_config", "reduced", "input_specs", "shape_applicable"]
