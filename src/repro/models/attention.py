"""GQA multi-head attention: full / chunked(flash-style) / KV-cache decode.

All projections route through ``qlinear`` (quantizable per the MKQ policy);
softmax is computed in fp32 (paper §5). Chunked attention is the jnp flash
pattern (scan over query blocks, running max/denominator) used for long
sequences where the (S, S) score tensor would not fit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import QuantSpec, apply_rope, init_linear, qlinear, rope_tables

NEG_INF = -2.0e38
KV_QUANT_SCALE = 1.0 / 16.0   # static int8 KV-cache scale (post-norm k/v are
                              # O(1); calibratable per-head in deployment)


def init_attention(key, d_model: int, n_heads: int, n_kv: int, hd: int,
                   qkv_bias: bool, out_bias: bool, stacked: int | None = None,
                   dtype=jnp.float32, fused: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    if fused:
        # one matmul + ONE backward-dx all-reduce instead of three (SS Perf)
        return {
            "wqkv": init_linear(ks[0], d_model, (n_heads + 2 * n_kv) * hd,
                                qkv_bias, stacked, dtype),
            "wo": init_linear(ks[3], n_heads * hd, d_model, out_bias,
                              stacked, dtype),
        }
    return {
        "wq": init_linear(ks[0], d_model, n_heads * hd, qkv_bias, stacked, dtype),
        "wk": init_linear(ks[1], d_model, n_kv * hd, qkv_bias, stacked, dtype),
        "wv": init_linear(ks[2], d_model, n_kv * hd, qkv_bias, stacked, dtype),
        "wo": init_linear(ks[3], n_heads * hd, d_model, out_bias, stacked, dtype),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    B, S, _ = x.shape
    return x.reshape(B, S, n, -1)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def full_attention(q, k, v, *, causal: bool, q_offset=0,
                   kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,H,dh), k/v: (B,Skv,H,dh) -> (B,Sq,H,dh). fp32 softmax."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    Sq, Skv = q.shape[1], k.shape[1]
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Skv)[None, :]
        scores = jnp.where((ki <= qi)[None, None], scores, NEG_INF)
    if kv_len is not None:  # mask cache positions beyond current length
        valid = jnp.arange(Skv)[None, None, None, :] < kv_len
        scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)


def chunked_attention(q, k, v, *, causal: bool, chunk: int,
                      skip_masked_blocks: bool = True,
                      seq_shard_axes=None) -> jax.Array:
    """Flash-style: scan over query blocks; online softmax over KV blocks.

    ``seq_shard_axes``: (dp_axes, model_axis) — context-parallel mode for
    archs whose head count doesn't divide the TP axis (e.g. 40 heads on 16):
    each query block's ROW dim is sharded over 'model' (k/v replicated per
    block), so the online-softmax inner loop is fully local — without this,
    GSPMD emits a per-KV-step accumulator all-reduce (EXPERIMENTS.md §Perf).
    """
    B, S, H, dh = q.shape
    nq = S // chunk
    assert S % chunk == 0, (S, chunk)
    qb = q.reshape(B, nq, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nq, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nq, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    if seq_shard_axes is not None:
        from jax.sharding import PartitionSpec as PS
        dp, tp = seq_shard_axes
        qb = jax.lax.with_sharding_constraint(
            qb, PS(None, dp, tp, None, None))
        kb = jax.lax.with_sharding_constraint(
            kb, PS(None, dp, None, None, None))
        vb = jax.lax.with_sharding_constraint(
            vb, PS(None, dp, None, None, None))
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    def q_block(qi, q_i):
        # online softmax state
        m = jnp.full((B, H, chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, chunk), jnp.float32)
        acc = jnp.zeros((B, chunk, H, dh), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_j, v_j = inputs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                qpos = qi * chunk + jnp.arange(chunk)[:, None]
                kpos = ki * chunk + jnp.arange(chunk)[None, :]
                s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p, v_j.astype(jnp.float32))
            if causal and skip_masked_blocks:
                # blocks strictly after the query block are fully masked: skip.
                keep = ki <= qi
                m_new = jnp.where(keep, m_new, m)
                l_new = jnp.where(keep, l_new, l)
                acc_new = jnp.where(keep, acc_new, acc)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m, l, acc), (jnp.arange(nq), kb, vb))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype)

    out_blocks = jax.lax.map(lambda args: q_block(*args),
                             (jnp.arange(nq), qb))
    return out_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)


def cached_decode_attention(q, k_cache, v_cache, k_new, v_new, length):
    """Decode attention: q (B,Sq,H,dh) over cache (B,Smax,H,dh) masked to
    ``length`` plus Sq new tokens (causal among themselves). fp32 softmax.

    ``length`` is a scalar (whole-batch cursor) or (B,) per-slot lengths —
    the serving slot table (repro/serving) refills slots independently, so
    each slot masks its own prefix of the cache.
    """
    B, Sq, H, dh = q.shape
    Smax = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    length = jnp.asarray(length)
    lb = length.reshape(-1, 1, 1, 1) if length.ndim else length
    valid = jnp.arange(Smax)[None, None, None, :] < lb
    s1 = jnp.where(valid, s1, NEG_INF)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", q, k_new).astype(jnp.float32) * scale
    if Sq > 1:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sq)[None, :]
        s2 = jnp.where((ki <= qi)[None, None], s2, NEG_INF)
    s = jax.nn.softmax(jnp.concatenate([s1, s2], axis=-1), axis=-1)
    p1, p2 = s[..., :Smax].astype(q.dtype), s[..., Smax:].astype(q.dtype)
    return (jnp.einsum("bhqk,bkhd->bqhd", p1, v_cache)
            + jnp.einsum("bhqk,bkhd->bqhd", p2, v_new))


def attention_block(x: jax.Array, p: dict, *, n_heads: int, n_kv: int, hd: int,
                    spec: QuantSpec, causal: bool = True, rope: bool = True,
                    rope_theta: float = 10000.0,
                    positions: Optional[jax.Array] = None,
                    cache: Optional[dict] = None,
                    kv_input: Optional[jax.Array] = None,
                    chunk: int = 0,
                    seq_shard_axes=None,
                    kv_len: Optional[jax.Array] = None,
                    want_taps: bool = False):
    """One attention sublayer (pre-norm residual handled by caller).

    cache: {'k': (B, S_max, n_kv, hd), 'v': ..., 'len': ()} -> decode mode.
    kv_input: cross-attention source (enc-dec); keys/values from this tensor.
    kv_len: (B,) per-row valid lengths for the cacheless path — keys at or
        past a row's length are masked before the softmax. This is what makes
        bucket-padded BIDIRECTIONAL (encoder) batches exact: causal models
        never see the zero tail, but a bidirectional row would attend it.
    Returns (out, new_cache, taps).
    """
    B, Sq, _ = x.shape
    src = x if kv_input is None else kv_input
    if "wqkv" in p:
        qkv = qlinear(x, p["wqkv"], spec)
        q, k, v = jnp.split(qkv, [n_heads * hd, (n_heads + n_kv) * hd], -1)
        q = _split_heads(q, n_heads)
        k = _split_heads(k, n_kv)
        v = _split_heads(v, n_kv)
    else:
        q = _split_heads(qlinear(x, p["wq"], spec), n_heads)
        k = _split_heads(qlinear(src, p["wk"], spec), n_kv)
        v = _split_heads(qlinear(src, p["wv"], spec), n_kv)
    taps = None
    if want_taps:
        taps = {"q": q.reshape(B, Sq, -1), "k": k.reshape(B, k.shape[1], -1),
                "v": v.reshape(B, v.shape[1], -1)}

    if positions is None:
        if cache is not None:
            off = jnp.asarray(cache["len"])  # scalar or (B,) per-slot
            positions = jnp.arange(Sq)[None, :] + (
                off[:, None] if off.ndim else off)
        else:
            positions = jnp.arange(Sq)[None, :]
    if rope and kv_input is None:
        cos, sin = rope_tables(positions, hd, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    groups = n_heads // n_kv
    if cache is not None and "k_q" in cache:
        # quantized KV cache (packed codes + per-row scales, DESIGN.md §8).
        # The deployed-int policy (spec.use_pallas) routes single-token
        # decode through the fused Pallas kernel — packed K/V blocks are
        # dequantized in VMEM inside the online-softmax loop; everything
        # else (fp policies, multi-token steps) takes the dequantize-then-
        # attend reference path. Both attend the new token's k/v at full
        # precision; what future steps see is decided by the quantize-on-
        # append cache write (models/transformer.write_new_kv).
        from ..kernels.kv_pack import dequantize_kv
        if spec.use_pallas and Sq == 1:
            from ..kernels import ops as kops
            out = kops.decode_attention(
                q[:, 0], cache["k_q"], cache["v_q"], cache["k_scale"],
                cache["v_scale"], k[:, 0], v[:, 0], cache["len"])[:, None]
        else:
            kk_c = _repeat_kv(dequantize_kv(cache["k_q"], cache["k_scale"],
                                            q.dtype), groups)
            vv_c = _repeat_kv(dequantize_kv(cache["v_q"], cache["v_scale"],
                                            q.dtype), groups)
            out = cached_decode_attention(q, kk_c, vv_c, _repeat_kv(k, groups),
                                          _repeat_kv(v, groups), cache["len"])
        new_cache = (k, v)
    elif cache is not None:
        # decode: attend over [cache (masked to len), new tokens] at the
        # SCORE level — the cache tensor is only read; the caller writes the
        # (B, Sq, Hkv, dh) new-token k/v at position ``len`` (one small DUS
        # instead of a full-cache copy per layer).
        if cache["k"].dtype == jnp.int8:   # static-scale int8 cache (legacy)
            kk_c = _repeat_kv(cache["k"].astype(q.dtype) * KV_QUANT_SCALE,
                              groups)
            vv_c = _repeat_kv(cache["v"].astype(q.dtype) * KV_QUANT_SCALE,
                              groups)
        else:
            kk_c = _repeat_kv(cache["k"].astype(q.dtype), groups)
            vv_c = _repeat_kv(cache["v"].astype(q.dtype), groups)
        kk_n = _repeat_kv(k, groups)
        vv_n = _repeat_kv(v, groups)
        out = cached_decode_attention(q, kk_c, vv_c, kk_n, vv_n,
                                      cache["len"])
        new_cache = (k, v)
    else:
        kk, vv = _repeat_kv(k, groups), _repeat_kv(v, groups)
        if (chunk and Sq > chunk and Sq % chunk == 0 and kv_input is None
                and kv_len is None):
            out = chunked_attention(q, kk, vv, causal=causal, chunk=chunk,
                                    seq_shard_axes=seq_shard_axes)
        else:
            out = full_attention(q, kk, vv, causal=causal and kv_input is None,
                                 kv_len=(None if kv_len is None else
                                         jnp.reshape(kv_len, (-1, 1, 1, 1))))
    out = out.reshape(B, Sq, n_heads * hd)
    return qlinear(out, p["wo"], spec), new_cache, taps


def init_cache(batch: int, max_len: int, n_kv: int, hd: int,
               dtype=jnp.bfloat16) -> dict:
    return {"k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
            "len": jnp.zeros((), jnp.int32)}


def cache_specs(batch: int, max_len: int, n_kv: int, hd: int,
                dtype=jnp.bfloat16) -> dict:
    return {"k": jax.ShapeDtypeStruct((batch, max_len, n_kv, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, n_kv, hd), dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}
