"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory) blocks.

mLSTM uses the CHUNKWISE-PARALLEL stabilized form (TFLA/mlstm-kernels style):
scan over sequence chunks carrying (C_hat, n_hat, m) with log-space running
max stabilization — intra-chunk quadratic term + inter-chunk state term.
Decode is the O(1) stabilized recurrence. sLSTM is a true time recurrence
(block-diagonal per-head hidden-to-hidden matrices) via ``lax.scan``.

Stack layout (xlstm-1.3b): groups of ``slstm_every`` layers =
(slstm_every - 1) mLSTM + 1 sLSTM, scanned over groups.

Quantized matmuls (MKQ): up/down projections, q/k/v projections, sLSTM input
matmul. Gates, norms, recurrences stay fp32. Attention-distribution distill is
inapplicable (no softmax attention) — hidden-state distill instead (DESIGN §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import QuantSpec, init_linear, init_norm, qlinear, rmsnorm
from .transformer import _slice_stack, mask_padded_vocab, scan_layers

CONV_K = 4


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.slstm_every
    assert cfg.num_layers % per == 0
    return cfg.num_layers // per, per


# ------------------------------------------------------------------ mLSTM core

def _headnorm(x, scale):
    """Per-head RMS norm over dh: x (B,S,H,dh), scale (H*dh,)."""
    B, S, H, dh = x.shape
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    return (y.reshape(B, S, H * dh) * scale).astype(x.dtype)


def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int, state=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,S,H,dh); i_pre,f_pre: (B,S,H) raw gate pre-activations.
    state: optional (C_hat (B,H,dh,dh), n_hat (B,H,dh), m (B,H)).
    Returns y (B,S,H,dh), final state.
    """
    B, S, H, dh = q.shape
    Q = min(chunk, S)
    nc = S // Q
    assert S % Q == 0
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))      # (B,S,H)
    li = i_pre.astype(jnp.float32)

    qc = q.reshape(B, nc, Q, H, dh).transpose(1, 0, 3, 2, 4)  # (nc,B,H,Q,dh)
    kc = k.reshape(B, nc, Q, H, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, Q, H, dh).transpose(1, 0, 3, 2, 4)
    lfc = lf.reshape(B, nc, Q, H).transpose(1, 0, 3, 2)       # (nc,B,H,Q)
    lic = li.reshape(B, nc, Q, H).transpose(1, 0, 3, 2)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C, n, m = carry
        q_i, k_i, v_i, lf_i, li_i = inp                      # (B,H,Q,...)
        b = jnp.cumsum(lf_i, axis=-1)                        # (B,H,Q)
        total = b[..., -1]
        # intra-chunk log decay D_ij = b_i - b_j + li_j  (j <= i)
        D = b[..., :, None] - b[..., None, :] + li_i[..., None, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                        # (B,H,Q)
        m_comb = jnp.maximum(m[..., None] + b, m_intra)
        inter_coef = jnp.exp(m[..., None] + b - m_comb)      # (B,H,Q)
        W = jnp.exp(D - m_comb[..., None])                   # (B,H,Q,Q)
        qf, kf, vf = (t.astype(jnp.float32) for t in (q_i, k_i, v_i))
        S_mat = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale * W
        h = (jnp.einsum("bhqd,bhde->bhqe", qf * inter_coef[..., None] * scale, C)
             + jnp.einsum("bhqk,bhkd->bhqd", S_mat, vf))
        denom_raw = (jnp.einsum("bhqd,bhd->bhq", qf * scale, n) * inter_coef
                     + jnp.sum(S_mat, axis=-1))
        denom = jnp.maximum(jnp.abs(denom_raw), jnp.exp(-m_comb))
        y = h / denom[..., None]
        # state update
        m_next = jnp.maximum(m + total,
                             jnp.max(total[..., None] - b + li_i, axis=-1))
        sdec = jnp.exp(total[..., None] - b + li_i - m_next[..., None])  # (B,H,Q)
        C_next = (jnp.exp(m + total - m_next)[..., None, None] * C
                  + jnp.einsum("bhq,bhqd,bhqe->bhde", sdec, kf, vf))
        n_next = (jnp.exp(m + total - m_next)[..., None] * n
                  + jnp.einsum("bhq,bhqd->bhd", sdec, kf))
        return (C_next, n_next, m_next), y

    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, lfc, lic))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)
    return y.astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, q, k, v, i_pre, f_pre):
    """One-token mLSTM. q,k,v: (B,1,H,dh); returns y (B,1,H,dh), new state."""
    C, n, m = state
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))[:, 0]  # (B,H)
    li = i_pre.astype(jnp.float32)[:, 0]
    m_new = jnp.maximum(lf + m, li)
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + m - m_new)
    qf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (q, k, v))  # (B,H,dh)
    C_new = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kf, vf)
    n_new = f_s[..., None] * n + i_s[..., None] * kf
    h = jnp.einsum("bhd,bhde->bhe", qf * scale, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf * scale, n_new)),
                        jnp.exp(-m_new))
    y = (h / denom[..., None])[:, None]
    return y.astype(q.dtype), (C_new, n_new, m_new)


# ------------------------------------------------------------------ blocks

def init_mlstm_block(key, cfg: ModelConfig, stacked=None) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    ks = jax.random.split(key, 7)
    shp = lambda *s: (stacked, *s) if stacked is not None else s
    return {
        "norm": init_norm(ks[0], d, "rms", stacked),
        "up": init_linear(ks[1], d, 2 * di, False, stacked),
        "conv_w": jax.random.normal(ks[2], shp(CONV_K, di)) * 0.1,
        "wq": init_linear(ks[3], di, di, False, stacked),
        "wk": init_linear(ks[4], di, di, False, stacked),
        "wv": init_linear(ks[5], di, di, False, stacked),
        "w_gates": {"w": jax.random.normal(ks[6], shp(di, 2 * H)) * 0.02,
                    "b": jnp.concatenate([jnp.zeros(shp(H)),
                                          3.0 * jnp.ones(shp(H))], -1)},
        "headnorm": jnp.ones(shp(di), jnp.float32),
        "down": init_linear(jax.random.fold_in(key, 9), di, d, False, stacked),
    }


def _causal_conv(u, w, cache=None):
    if cache is not None:
        u_ext = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
        new_cache = u_ext[:, -(CONV_K - 1):]
    else:
        u_ext = jnp.pad(u, ((0, 0), (CONV_K - 1, 0), (0, 0)))
        new_cache = None
    S = u.shape[1]
    out = sum(u_ext[:, i:i + S] * w[i] for i in range(CONV_K))
    return out, new_cache


def mlstm_block(x, p, cfg: ModelConfig, spec: QuantSpec, state=None):
    """state: {'C','n','m','conv'} for decode; None for train/prefill."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = di // H
    h = rmsnorm(x, p["norm"]["scale"])
    xz = qlinear(h, p["up"], spec)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_out, new_conv = _causal_conv(
        xi, p["conv_w"], None if state is None else state["conv"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    q = qlinear(conv_out, p["wq"], spec).reshape(B, S, H, dh)
    k = qlinear(conv_out, p["wk"], spec).reshape(B, S, H, dh)
    v = qlinear(xi, p["wv"], spec).reshape(B, S, H, dh)
    gates = (conv_out.astype(jnp.float32) @ p["w_gates"]["w"]
             + p["w_gates"]["b"])                            # (B,S,2H)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    if state is None:
        y, _ = mlstm_chunked(q, k, v, i_pre, f_pre, cfg.ssm_chunk)
        new_state = None
    else:
        y, (C, n, m) = mlstm_decode_step(
            (state["C"], state["n"], state["m"]), q, k, v, i_pre, f_pre)
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    y = _headnorm(y, p["headnorm"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + qlinear(y, p["down"], spec), new_state


def init_slstm_block(key, cfg: ModelConfig, stacked=None) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    shp = lambda *s: (stacked, *s) if stacked is not None else s
    return {
        "norm": init_norm(ks[0], d, "rms", stacked),
        "w_in": init_linear(ks[1], d, 4 * d, False, stacked),
        "r": jax.random.normal(ks[2], shp(4, H, dh, dh)) * 0.02,
        "b": jnp.zeros(shp(4 * d)),
        "down": init_linear(ks[3], d, d, False, stacked),
    }


def slstm_block(x, p, cfg: ModelConfig, spec: QuantSpec, state=None):
    """Scalar-memory LSTM with per-head block-diagonal recurrence (scan over t).

    state: {'c','n','m','h'} each (B, d) for decode.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    hin = rmsnorm(x, p["norm"]["scale"])
    wx = qlinear(hin, p["w_in"], spec).astype(jnp.float32) + p["b"]  # (B,S,4d)

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, m0, h0 = (state[k] for k in ("c", "n", "m", "h"))

    r = p["r"].astype(jnp.float32)                           # (4,H,dh,dh)

    def step(carry, wx_t):
        c, n, m, h = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,ghde->gbhe", hh, r).reshape(4, B, d)
        zi, zf, zz, zo = jnp.split(wx_t, 4, -1)
        i_pre = zi + rec[0]
        f_pre = zf + rec[1]
        zt = jnp.tanh(zz + rec[2])
        ot = jax.nn.sigmoid(zo + rec[3])
        lf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(lf + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = ot * c_new / n_new
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), ys = jax.lax.scan(step, (c0, n0, m0, h0),
                                    wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)                # (B,S,d)
    new_state = None
    if state is not None:
        new_state = {"c": c, "n": n, "m": m, "h": h}
    return x + qlinear(y, p["down"], spec), new_state


# ------------------------------------------------------------------ full stack

def init_xlstm(cfg: ModelConfig, key) -> dict:
    G, per = _groups(cfg)
    n_m = per - 1
    ks = jax.random.split(key, 5)
    mflat = init_mlstm_block(ks[0], cfg, stacked=G * n_m)
    mstack = jax.tree.map(lambda a: a.reshape(G, n_m, *a.shape[1:]), mflat)
    return {
        "embed": jax.random.normal(ks[1], (cfg.padded_vocab, cfg.d_model)) * 0.02,
        "mlstm": mstack,
        "slstm": init_slstm_block(ks[2], cfg, stacked=G),
        "final_norm": init_norm(ks[3], cfg.d_model, "rms"),
        "lm_head": jax.random.normal(ks[4], (cfg.d_model, cfg.padded_vocab)) * 0.02,
    }


def xlstm_forward(params, cfg: ModelConfig, segments, *, tokens=None,
                  states: Optional[dict] = None, want_taps: bool = False,
                  **_unused):
    """Group scan: (per-1) mLSTM + 1 sLSTM per group; segments over groups."""
    G, per = _groups(cfg)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    presliced = isinstance(params["mlstm"], (list, tuple))
    with_state = states is not None

    def make_body(spec):
        def inner(carry, xs):
            h = carry
            if with_state:
                lp, st = xs
                h2, ns = mlstm_block(h, lp, cfg, spec, state=st)
                return h2, ns
            h2, _ = mlstm_block(h, xs, cfg, spec)
            return h2, jnp.zeros((), jnp.float32)

        def body(carry, xs):
            if with_state:
                # states ride the carry; per-group slices updated in place
                h, st = carry
                (mp, sp), idx = xs
                mst = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                    st["mlstm"])
                sst = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                    st["slstm"])
                h, new_mst = jax.lax.scan(inner, h, (mp, mst))
                h, new_sst = slstm_block(h, sp, cfg, spec, state=sst)
                upd = lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0)
                st = {"mlstm": jax.tree.map(upd, st["mlstm"], new_mst),
                      "slstm": jax.tree.map(upd, st["slstm"], new_sst)}
                return (h, st), None
            h = carry
            mp, sp = xs
            h, _ = scan_layers(inner, h, mp)
            h, _ = slstm_block(h, sp, cfg, spec)
            return h, jnp.zeros((), jnp.float32)
        return body

    out_states = states
    for si, (start, end, spec) in enumerate(segments):
        mseg = (params["mlstm"][si] if presliced
                else _slice_stack(params["mlstm"], start, end))
        sseg = (params["slstm"][si] if presliced
                else _slice_stack(params["slstm"], start, end))
        body = make_body(spec)
        if cfg.remat:
            body = jax.checkpoint(body)
        if with_state:
            idxs = jnp.arange(start, end)
            (x, out_states), _ = jax.lax.scan(body, (x, out_states),
                                              ((mseg, sseg), idxs))
        else:
            x, _ = scan_layers(body, x, (mseg, sseg))

    taps = {"hidden": x} if want_taps else None
    x = rmsnorm(x, params["final_norm"]["scale"])
    logits = mask_padded_vocab(x @ params["lm_head"].astype(x.dtype), cfg)
    return logits, out_states, taps, jnp.zeros((), jnp.float32)


def xlstm_states(cfg: ModelConfig, batch: int, as_specs: bool = False) -> dict:
    G, per = _groups(cfg)
    n_m = per - 1
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = di // H
    f32 = jnp.float32
    mk = (lambda s: jax.ShapeDtypeStruct(s, f32)) if as_specs else (
        lambda s: jnp.zeros(s, f32))
    neg = (lambda s: jax.ShapeDtypeStruct(s, f32)) if as_specs else (
        lambda s: jnp.full(s, -1e30, f32))
    return {
        "mlstm": {"C": mk((G, n_m, batch, H, dh, dh)),
                  "n": mk((G, n_m, batch, H, dh)),
                  "m": neg((G, n_m, batch, H)),
                  "conv": mk((G, n_m, batch, CONV_K - 1, di))},
        "slstm": {"c": mk((G, batch, d)), "n": mk((G, batch, d)),
                  "m": neg((G, batch, d)), "h": mk((G, batch, d))},
    }
