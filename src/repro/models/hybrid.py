"""Zamba2-style hybrid stack: Mamba2 backbone + ONE shared attention block.

The shared attention+FFN block (a single weight set) is invoked after every
``attn_every``-th Mamba2 layer (arXiv:2411.15242). We therefore structure the
stack as ``G = L / attn_every`` groups; a group = ``attn_every`` stacked Mamba2
layers (inner scan) followed by one shared-block invocation. Each invocation
owns a KV cache slot (stacked over G) for decode.

Quantization policy granularity is the GROUP for the Mamba2 stack; the shared
block (one weight set reused G times) is quantized at ``default_bits``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.policy import QuantPolicy
from .attention import attention_block, init_attention
from .layers import QuantSpec, init_norm, rmsnorm
from .mamba2 import init_mamba2_block, mamba2_block, mamba2_state_init
from .transformer import (ffn_apply, init_ffn, _slice_stack,
                          mask_padded_vocab, scan_layers)


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.attn_every
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, per


def group_segments(policy: QuantPolicy, num_groups: int, use_pallas=False,
                   act_bits: int | None = None
                   ) -> list[tuple[int, int, QuantSpec]]:
    """Policy at group granularity: group g gets the bits of its first layer.
    ``act_bits`` is the plan-level activation override (DESIGN.md §13)."""
    per = policy.num_layers // num_groups
    segs: list[tuple[int, int, QuantSpec]] = []
    for g in range(num_groups):
        wb = policy.weight_bits(g * per) or 0
        ab = policy.act_bits(g * per) or 0
        if act_bits is not None and wb:
            ab = act_bits
        spec = QuantSpec(mode=policy.mode, w_bits=wb, a_bits=ab,
                         grad_mode=policy.grad_mode, use_pallas=use_pallas)
        if segs and segs[-1][2] == spec:
            segs[-1] = (segs[-1][0], g + 1, spec)
        else:
            segs.append((g, g + 1, spec))
    return segs


def init_hybrid(cfg: ModelConfig, key) -> dict:
    G, per = _groups(cfg)
    ks = jax.random.split(key, 8)
    # stacked (G, per, ...) mamba params: init as (G*per) then reshape leaves
    flat = init_mamba2_block(ks[0], cfg, stacked=G * per)
    mamba = jax.tree.map(lambda a: a.reshape(G, per, *a.shape[1:]), flat)
    return {
        "embed": jax.random.normal(ks[1], (cfg.padded_vocab, cfg.d_model)) * 0.02,
        "mamba": mamba,
        "shared": {
            "ln1": init_norm(ks[2], cfg.d_model, "rms"),
            "attn": init_attention(ks[3], cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.hd, cfg.qkv_bias,
                                   cfg.out_bias),
            "ln2": init_norm(ks[4], cfg.d_model, "rms"),
            "ffn": init_ffn(ks[5], cfg, None),
        },
        "final_norm": init_norm(ks[6], cfg.d_model, "rms"),
        "lm_head": jax.random.normal(ks[7], (cfg.d_model, cfg.padded_vocab)) * 0.02,
    }


def _shared_block(x, p, cfg: ModelConfig, spec: QuantSpec, cache=None):
    h = rmsnorm(x, p["ln1"]["scale"])
    chunk = cfg.attn_chunk if x.shape[1] > cfg.attn_chunk_threshold else 0
    a, new_cache, _ = attention_block(
        h, p["attn"], n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, hd=cfg.hd,
        spec=spec, causal=True, rope=True, rope_theta=cfg.rope_theta,
        cache=cache, chunk=chunk)
    x = x + a
    x = x + ffn_apply(rmsnorm(x, p["ln2"]["scale"]), p["ffn"], cfg, spec)
    return x, new_cache


def hybrid_forward(params, cfg: ModelConfig, segments, *, tokens=None,
                   states: Optional[dict] = None, want_taps: bool = False,
                   **_unused):
    """states: {'mamba': stacked (G,per,...) ssm/conv, 'attn': stacked (G,...) kv}."""
    G, per = _groups(cfg)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    presliced = isinstance(params["mamba"], (list, tuple))
    shared_spec = segments[-1][2]  # shared block: default-bits spec of last seg
    taps = None

    def make_group_body(spec, with_state):
        def inner(carry, xs):
            h = carry
            if with_state:
                lp, st = xs
                h2, ns = mamba2_block(h, lp, cfg, spec, state=st)
                return h2, ns
            h2, _ = mamba2_block(h, xs, cfg, spec)
            return h2, jnp.zeros((), jnp.float32)

        def body(carry, xs):
            if with_state:
                # states ride the carry; per-group slices updated in place
                h, st = carry
                lp, idx = xs
                mst = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                    st["mamba"])
                ac = st["attn"]
                acache = {
                    "k": jax.lax.dynamic_index_in_dim(ac["k"], idx, 0, False),
                    "v": jax.lax.dynamic_index_in_dim(ac["v"], idx, 0, False),
                    "len": ac["len"],
                }
                h, new_mst = jax.lax.scan(inner, h, (lp, mst))
                h, (k_new, v_new) = _shared_block(h, params["shared"], cfg,
                                                  shared_spec, cache=acache)
                upd = lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0)
                from .transformer import _to_cache
                start = (idx, 0, ac["len"], 0, 0)
                new_attn = {
                    "k": jax.lax.dynamic_update_slice(
                        ac["k"], _to_cache(k_new, ac["k"].dtype)[None], start),
                    "v": jax.lax.dynamic_update_slice(
                        ac["v"], _to_cache(v_new, ac["v"].dtype)[None], start),
                    "len": ac["len"],
                }
                st = {"mamba": jax.tree.map(upd, st["mamba"], new_mst),
                      "attn": new_attn}
                return (h, st), None
            h = carry
            lp = xs
            h, _ = scan_layers(inner, h, lp)
            h, _ = _shared_block(h, params["shared"], cfg, shared_spec)
            return h, jnp.zeros((), jnp.float32)
        return body

    out_states = states
    for si, (start, end, spec) in enumerate(segments):
        seg_m = (params["mamba"][si] if presliced
                 else _slice_stack(params["mamba"], start, end))
        body = make_group_body(spec, states is not None)
        if cfg.remat:
            body = jax.checkpoint(body)
        if states is not None:
            idxs = jnp.arange(start, end)
            (x, out_states), _ = jax.lax.scan(body, (x, out_states),
                                              (seg_m, idxs))
        else:
            x, _ = scan_layers(body, x, seg_m)

    if want_taps:  # last shared-attn invocation taps (attention part only)
        h = rmsnorm(x, params["shared"]["ln1"]["scale"])
        _, _, taps = attention_block(
            h, params["shared"]["attn"], n_heads=cfg.num_heads,
            n_kv=cfg.num_kv_heads, hd=cfg.hd, spec=shared_spec, causal=True,
            rope=True, rope_theta=cfg.rope_theta, want_taps=True)
        taps["hidden"] = x

    if out_states is not None:
        out_states = {**out_states,
                      "attn": {**out_states["attn"],
                               "len": out_states["attn"]["len"] + x.shape[1]}}
    x = rmsnorm(x, params["final_norm"]["scale"])
    logits = mask_padded_vocab(x @ params["lm_head"].astype(x.dtype), cfg)
    return logits, out_states, taps, jnp.zeros((), jnp.float32)


def hybrid_states(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16, as_specs: bool = False) -> dict:
    G, per = _groups(cfg)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
        lambda s, d: jnp.zeros(s, d))
    m1 = mamba2_state_init(cfg, batch, as_specs=as_specs)
    mamba = jax.tree.map(
        lambda a: (jax.ShapeDtypeStruct((G, per) + a.shape, a.dtype)
                   if as_specs else jnp.zeros((G, per) + a.shape, a.dtype)),
        m1)
    attn = {"k": mk((G, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
            "v": mk((G, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
            "len": mk((), jnp.int32)}
    return {"mamba": mamba, "attn": attn}
