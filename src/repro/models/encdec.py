"""Encoder-decoder backbone (Seamless-M4T medium shape).

The modality frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, d_model) for the encoder. The decoder
is a standard causal stack with cross-attention; decode shapes exercise the
decoder with a cached self-attn KV and cached cross-attn K/V (computed once
from the encoder output at prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention_block, init_attention
from .layers import init_norm
from .transformer import (_norm, _slice_stack, ffn_apply, init_ffn,
                           mask_padded_vocab, scan_layers)


def init_encdec(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 9)
    d = cfg.d_model
    enc_block = {
        "ln1": init_norm(ks[0], d, cfg.norm, cfg.enc_layers),
        "attn": init_attention(ks[1], d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.hd, cfg.qkv_bias, cfg.out_bias,
                               cfg.enc_layers),
        "ln2": init_norm(ks[2], d, cfg.norm, cfg.enc_layers),
        "ffn": init_ffn(ks[3], cfg, cfg.enc_layers),
    }
    dec_block = {
        "ln1": init_norm(ks[4], d, cfg.norm, cfg.dec_layers),
        "self": init_attention(ks[5], d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.hd, cfg.qkv_bias, cfg.out_bias,
                               cfg.dec_layers),
        "ln2": init_norm(ks[4], d, cfg.norm, cfg.dec_layers),
        "cross": init_attention(ks[6], d, cfg.num_heads, cfg.num_kv_heads,
                                cfg.hd, cfg.qkv_bias, cfg.out_bias,
                                cfg.dec_layers),
        "ln3": init_norm(ks[4], d, cfg.norm, cfg.dec_layers),
        "ffn": init_ffn(ks[7], cfg, cfg.dec_layers),
    }
    return {
        "embed": jax.random.normal(ks[8], (cfg.padded_vocab, d)) * 0.02,
        "enc": enc_block,
        "dec": dec_block,
        "enc_norm": init_norm(ks[0], d, cfg.norm),
        "final_norm": init_norm(ks[0], d, cfg.norm),
        "lm_head": jax.random.normal(
            jax.random.fold_in(ks[8], 1), (d, cfg.padded_vocab)) * 0.02,
    }


def _enc_block(x, p, cfg, spec):
    a, _, _ = attention_block(
        _norm(x, p["ln1"], cfg.norm), p["attn"], n_heads=cfg.num_heads,
        n_kv=cfg.num_kv_heads, hd=cfg.hd, spec=spec, causal=False,
        rope=cfg.rope, rope_theta=cfg.rope_theta,
        chunk=cfg.attn_chunk if x.shape[1] > cfg.attn_chunk_threshold else 0)
    x = x + a
    return x + ffn_apply(_norm(x, p["ln2"], cfg.norm), p["ffn"], cfg, spec)


def _dec_block(x, enc_out, p, cfg, spec, cache=None, cross_kv=None,
               want_taps=False):
    a, new_cache, taps = attention_block(
        _norm(x, p["ln1"], cfg.norm), p["self"], n_heads=cfg.num_heads,
        n_kv=cfg.num_kv_heads, hd=cfg.hd, spec=spec, causal=True,
        rope=cfg.rope, rope_theta=cfg.rope_theta, cache=cache,
        chunk=cfg.attn_chunk if x.shape[1] > cfg.attn_chunk_threshold else 0,
        want_taps=want_taps)
    x = x + a
    c, _, _ = attention_block(
        _norm(x, p["ln2"], cfg.norm), p["cross"], n_heads=cfg.num_heads,
        n_kv=cfg.num_kv_heads, hd=cfg.hd, spec=spec, causal=False,
        rope=False, kv_input=enc_out, cache=None)
    x = x + c
    x = x + ffn_apply(_norm(x, p["ln3"], cfg.norm), p["ffn"], cfg, spec)
    return x, new_cache, taps


def encdec_forward(params, cfg: ModelConfig, segments, *, tokens=None,
                   src_embeds=None, enc_out=None, caches=None,
                   want_taps: bool = False, **_unused):
    """Train/prefill: src_embeds + tokens. Decode: tokens (B,1) + caches + enc_out.

    Segments apply to the DECODER stack (the quantization-sensitive, deployed
    half); the encoder uses the first segment's spec uniformly.
    """
    enc_spec = segments[0][2]
    presliced = isinstance(params["dec"], (list, tuple))
    if enc_out is None:
        h = src_embeds.astype(cfg.compute_dtype)

        def enc_body(carry, lp):
            return _enc_block(carry, lp, cfg, enc_spec), None
        body = jax.checkpoint(enc_body) if cfg.remat else enc_body
        h, _ = scan_layers(body, h, params["enc"])
        enc_out = _norm(h, params["enc_norm"], cfg.norm)

    x = params["embed"][tokens].astype(cfg.compute_dtype)
    taps = None
    for si, (start, end, spec) in enumerate(segments):
        is_last = si == len(segments) - 1
        n_scan = end - start - (1 if (want_taps and is_last) else 0)
        seg_full = (params["dec"][si] if presliced
                    else _slice_stack(params["dec"], start, end))
        seg = _slice_stack(seg_full, 0, n_scan)

        def write_new_kv(cs, idx, new_kv):
            k_new, v_new = new_kv
            start = (idx, 0, cs["len"], 0, 0)
            from .transformer import _to_cache
            return {
                "k": jax.lax.dynamic_update_slice(
                    cs["k"], _to_cache(k_new, cs["k"].dtype)[None], start),
                "v": jax.lax.dynamic_update_slice(
                    cs["v"], _to_cache(v_new, cs["v"].dtype)[None], start),
                "len": cs["len"],
            }

        def body(carry, xs):
            if caches is not None:
                # caches ride the carry: read layer slice, write one token
                h, cs = carry
                lp, idx = xs
                cache_l = {
                    "k": jax.lax.dynamic_index_in_dim(cs["k"], idx, 0, False),
                    "v": jax.lax.dynamic_index_in_dim(cs["v"], idx, 0, False),
                    "len": cs["len"],
                }
                h2, nc, _ = _dec_block(h, enc_out, lp, cfg, spec,
                                       cache=cache_l)
                return (h2, write_new_kv(cs, idx, nc)), None
            h2, _, _ = _dec_block(carry, enc_out, xs, cfg, spec)
            return h2, None

        if cfg.remat:
            body = jax.checkpoint(body)
        if n_scan > 0:
            if caches is not None:
                idxs = jnp.arange(start, start + n_scan)
                (x, caches), _ = jax.lax.scan(body, (x, caches), (seg, idxs))
            else:
                x, _ = scan_layers(body, x, seg)
        if want_taps and is_last:
            lp = jax.tree.map(lambda a: a[-1], seg_full)
            cache_l = None
            if caches is not None:
                cache_l = {"k": caches["k"][end - 1],
                           "v": caches["v"][end - 1], "len": caches["len"]}
            x, nc, taps = _dec_block(x, enc_out, lp, cfg, spec, cache=cache_l,
                                     want_taps=True)
            if caches is not None:
                k_new, v_new = nc
                start = (end - 1, 0, caches["len"], 0, 0)
                from .transformer import _to_cache
                caches = {
                    "k": jax.lax.dynamic_update_slice(
                        caches["k"], _to_cache(k_new, caches["k"].dtype)[None],
                        start),
                    "v": jax.lax.dynamic_update_slice(
                        caches["v"], _to_cache(v_new, caches["v"].dtype)[None],
                        start),
                    "len": caches["len"]}

    new_caches = None
    if caches is not None:
        new_caches = {**caches, "len": caches["len"] + x.shape[1]}
    x = _norm(x, params["final_norm"], cfg.norm)
    logits = mask_padded_vocab(x @ params["lm_head"].astype(x.dtype), cfg)
    return logits, new_caches, taps, jnp.zeros((), jnp.float32)
