from . import (api, attention, bert, encdec, hybrid, layers, mamba2,  # noqa: F401
               transformer, xlstm)
