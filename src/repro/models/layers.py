"""Shared layer primitives: the quantizable linear, norms, activations, RoPE.

Structural quantization rule (paper §5): ONLY matmul inputs/weights are
quantized. LayerNorm/RMSNorm, softmax and GELU/SiLU run in fp32. The embedding
table is never quantized.

``qlinear`` is the single quantized-matmul primitive used by every arch:

  mode 'none'  : x @ w            (fp baseline / teacher)
  mode 'fake'  : Q_a[x] @ Q_w[w]  (QAT; LSQ with 'mse' or 'ste' scale grads)
  mode 'int'   : int8 codes matmul'd on the integer unit with fused dequant;
                 weights arrive pre-quantized (packed int4 or int8) via
                 core.packing. Optionally dispatches to the Pallas TPU kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.quantizer import fake_quant, quantize_to_int
from ..core.packing import unpack_int4

__all__ = ["QuantSpec", "qlinear", "rmsnorm", "layernorm", "gelu_f32",
           "rope_tables", "apply_rope", "init_linear", "init_norm", "act_fn"]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static per-call quantization spec (bits vary per layer-SEGMENT, not per step)."""
    mode: str = "none"          # none | fake | int
    w_bits: int = 0             # 0 = unquantized
    a_bits: int = 0
    grad_mode: str = "mse"
    use_pallas: bool = False    # int mode: pallas kernels (TPU) vs jnp int path
    fuse_epilogue: bool = False  # int4 pallas: fold bias+act into the matmul

    @property
    def enabled(self) -> bool:
        return self.mode != "none" and self.w_bits > 0

    def with_mode(self, mode: str) -> "QuantSpec":
        return dataclasses.replace(self, mode=mode)


NONE_SPEC = QuantSpec()


def _int_matmul_jnp(x8: jax.Array, w8: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 dot (native on the TPU MXU)."""
    return jax.lax.dot_general(
        x8, w8, (((x8.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def qlinear(x: jax.Array, p: dict, spec: QuantSpec,
            act: Optional[str] = None) -> jax.Array:
    """Quantizable linear. p holds either fp or deployed-int parameters.

    fp params:  {'w': (K, N), 'b': (N,)?, 's_w': (1, N), 's_a': ()}
    int params: {'wq': packed, 's_w': (1, N), 's_a': (), 'b': (N,)?, 'w_bits': static}

    ``act`` (fused-epilogue callers only): fold this activation into the int4
    Pallas kernel's epilogue together with dequant+bias. Only valid on the
    deployed int4 Pallas path — the caller must apply the activation itself
    everywhere else (see ffn_apply).
    """
    from ..core import calibration
    if calibration.active():
        calibration.record_input(x)
    b = p.get("b")
    if spec.mode == "int":
        return _qlinear_int(x, p, spec, act=act)
    assert act is None, "fused act requires the deployed int4 Pallas path"
    w = p["w"]
    if spec.mode == "fake" and spec.enabled:
        w = fake_quant(w, p["s_w"], spec.w_bits, spec.grad_mode)
        if spec.a_bits:
            x = fake_quant(x, p["s_a"], spec.a_bits, spec.grad_mode)
    out = x @ w.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def _qlinear_int(x: jax.Array, p: dict, spec: QuantSpec,
                 act: Optional[str] = None) -> jax.Array:
    """Deployed integer path. Activations quantized on the fly (per-tensor
    scale); ``a_bits == 0`` keeps them fp against dequantized weights — the
    weight-only parity baseline for the integer-accumulation path
    (DESIGN.md §13; reference backend only, plan-validated)."""
    s_a, s_w = p["s_a"], p["s_w"]
    a_bits = spec.a_bits
    b = p.get("b")
    if a_bits == 0:
        assert not spec.use_pallas and act is None, \
            "fp-activation fallback is reference-backend only"
        w8 = unpack_int4(p["wq"], axis=-2) if spec.w_bits == 4 else p["wq"]
        k = x.shape[-1]
        if w8.shape[-2] != k:  # drop int4 pack padding row if any
            w8 = jax.lax.slice_in_dim(w8, 0, k, axis=-2)
        w = (w8.astype(jnp.float32) * s_w).astype(x.dtype)
        out = x @ w
        if b is not None:
            out = out + b.astype(out.dtype)
        return out
    if spec.use_pallas:
        from ..kernels import ops as kops  # lazy: keeps CPU-only paths pallas-free
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if spec.w_bits == 4:
            if act is not None:
                # fused decode path: dequant + bias + activation inside the
                # kernel epilogue — no materialized (M, N) intermediate
                out = kops.int4_matmul(x2, p["wq"], s_a, s_w, a_bits=a_bits,
                                       bias=b, act=act)
                return out.reshape(*lead, -1)
            out = kops.int4_matmul(x2, p["wq"], s_a, s_w, a_bits=a_bits)
        else:
            assert act is None, "fused epilogue is int4-only"
            out = kops.int8_matmul(x2, p["wq"], s_a, s_w, a_bits=a_bits)
        out = out.reshape(*lead, -1)
    else:
        assert act is None, "fused act requires the int4 Pallas path"
        x8 = quantize_to_int(x, s_a, a_bits)
        w8 = unpack_int4(p["wq"], axis=-2) if spec.w_bits == 4 else p["wq"]
        k = x.shape[-1]
        if w8.shape[-2] != k:  # drop int4 pack padding row if any
            w8 = jax.lax.slice_in_dim(w8, 0, k, axis=-2)
        acc = _int_matmul_jnp(x8, w8)
        out = (acc.astype(jnp.float32) * (s_a * s_w)).astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


# ---------------------------------------------------------------- norms/acts
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def gelu_f32(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def act_fn(name: str):
    return {"gelu": gelu_f32,
            "silu": lambda x: (jax.nn.silu(x.astype(jnp.float32))).astype(x.dtype),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------- RoPE
def rope_tables(positions: jax.Array, dim: int, theta: float = 10000.0):
    """cos/sin tables for positions: (..., S) -> (..., S, dim/2) each, f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); cos/sin: (B_or_1, S, dh/2) broadcast over heads."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# ----------------------------------------------------------------- inits
def init_linear(key, k: int, n: int, bias: bool, stacked: int | None = None,
                dtype=jnp.float32) -> dict:
    """fp linear params (+ unit quant scales, calibrated later)."""
    shape = (k, n) if stacked is None else (stacked, k, n)
    std = 0.02
    p = {"w": jax.random.normal(key, shape, dtype) * std,
         "s_w": jnp.ones(shape[:-2] + (1, n), jnp.float32),
         "s_a": jnp.ones(shape[:-2], jnp.float32)}
    if bias:
        p["b"] = jnp.zeros(shape[:-2] + (n,), dtype)
    return p


def init_norm(k_unused, d: int, kind: str, stacked: int | None = None,
              dtype=jnp.float32) -> dict:
    shape = (d,) if stacked is None else (stacked, d)
    p = {"scale": jnp.ones(shape, dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros(shape, dtype)
    return p
