"""Family-dispatching model API: init / forward / decode-state for every arch.

forward(...) -> (logits, new_state, taps, aux_loss) uniformly across families,
so train/serve/dryrun drivers are architecture-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.policy import QuantPolicy
from .layers import QuantSpec
from . import encdec, hybrid, transformer, xlstm


def segments_for(cfg: ModelConfig, policy: Optional[QuantPolicy],
                 use_pallas: bool = False, fuse_epilogue: bool = False):
    if policy is None:
        n = _segment_units(cfg)
        return [(0, n, QuantSpec())]
    if cfg.family in ("xlstm", "hybrid"):
        per = cfg.slstm_every if cfg.family == "xlstm" else cfg.attn_every
        return hybrid.group_segments(policy, cfg.num_layers // per, use_pallas)
    if cfg.family == "encdec":
        # segments over decoder layers
        assert policy.num_layers == cfg.dec_layers, \
            f"encdec policy covers decoder layers ({cfg.dec_layers})"
    return transformer.segments_from_policy(policy, use_pallas, fuse_epilogue)


def _segment_units(cfg: ModelConfig) -> int:
    if cfg.family == "xlstm":
        return cfg.num_layers // cfg.slstm_every
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.dec_layers
    return cfg.num_layers


def init_model(cfg: ModelConfig, key) -> dict:
    if cfg.family == "xlstm":
        return xlstm.init_xlstm(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def forward(params, cfg: ModelConfig, segments, *, state=None,
            want_taps: bool = False, **inputs):
    """inputs: tokens / src_embeds / patch_embeds / patch_mask / enc_out."""
    if cfg.family == "xlstm":
        return xlstm.xlstm_forward(params, cfg, segments, states=state,
                                   want_taps=want_taps, **inputs)
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(params, cfg, segments, states=state,
                                     want_taps=want_taps, **inputs)
    if cfg.family == "encdec":
        return encdec.encdec_forward(params, cfg, segments, caches=state,
                                     want_taps=want_taps, **inputs)
    return transformer.lm_forward(params, cfg, segments, caches=state,
                                  want_taps=want_taps, **inputs)


def decode_state(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, as_specs: bool = False,
                 per_slot_len: bool = False,
                 kv_bits: Optional[int] = None):
    """per_slot_len=True allocates a (batch,) length vector instead of the
    scalar cursor, so a serving slot table can refill slots independently
    (transformer-family KV caches only).

    kv_bits 8/4 allocates the quantized packed cache layout (DESIGN.md §8)
    instead of fp K/V rows (transformer-family caches only); the default
    (None) follows ``cfg.kv_bits`` so the config knob means the same thing
    to every caller."""
    kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
    if cfg.family == "xlstm":
        if per_slot_len or kv_bits != 16:
            raise ValueError(
                "per_slot_len/kv_bits: transformer-family caches only")
        return xlstm.xlstm_states(cfg, batch, as_specs=as_specs)
    if cfg.family == "hybrid":
        if per_slot_len or kv_bits != 16:
            raise ValueError(
                "per_slot_len/kv_bits: transformer-family caches only")
        return hybrid.hybrid_states(cfg, batch, max_len, dtype, as_specs)
    if cfg.family == "encdec":
        if per_slot_len or kv_bits != 16:
            raise ValueError(
                "per_slot_len/kv_bits: transformer-family caches only")
        L = cfg.dec_layers
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
            lambda s, d: jnp.zeros(s, d))
        return {"k": mk((L, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
                "v": mk((L, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
                "len": mk((), jnp.int32)}
    return transformer.lm_caches(cfg, batch, max_len, dtype, as_specs,
                                 per_slot_len=per_slot_len, kv_bits=kv_bits)


def decode_extra_inputs(cfg: ModelConfig, batch: int, src_len: int,
                        dtype=jnp.bfloat16, as_specs: bool = False) -> dict:
    """Family-specific extra decode inputs (enc-dec needs encoder output)."""
    if cfg.family == "encdec":
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
            lambda s, d: jnp.zeros(s, d))
        return {"enc_out": mk((batch, src_len, cfg.d_model), dtype)}
    return {}
