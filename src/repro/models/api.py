"""Family-dispatching model API: init / forward / decode-state for every arch.

``forward(params, plan, ...) -> (logits, new_state, taps, aux_loss)``
uniformly across families, so train/serve/dryrun drivers are
architecture-agnostic. The ``plan`` is a ``repro.deploy.ExecutionPlan``
(DESIGN.md §9) carrying the resolved cfg + segments; the legacy
``forward(params, cfg, segments, ...)`` positional form is kept as a thin
deprecation shim for existing tests and fp training call sites.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.policy import QuantPolicy
from .layers import QuantSpec  # noqa: F401  (re-export: segment spec type)
from . import encdec, hybrid, transformer, xlstm


def segments_for(cfg: ModelConfig, policy: Optional[QuantPolicy],
                 use_pallas: bool = False, fuse_epilogue: bool = False):
    """DEPRECATED shim — build a ``repro.deploy.ExecutionPlan`` instead.

    The kernel-selection booleans live on the plan now
    (``backend='pallas'`` / ``fuse_epilogue``); this shim only remains so
    policy→segment resolution stays importable from the models layer and
    plan-equivalence tests can compare against the legacy combinations.
    """
    from ..deploy.plan import resolve_segments
    return resolve_segments(cfg, policy, use_pallas, fuse_epilogue)


def _unpack_plan(plan, segments):
    """(plan) or legacy (cfg, segments) → (cfg, segments)."""
    if isinstance(plan, ModelConfig):
        if segments is None:
            raise TypeError(
                "forward(params, cfg, segments) needs segments; pass an "
                "ExecutionPlan instead (repro.deploy.ExecutionPlan.build)")
        return plan, segments
    return plan.cfg, plan.segments


def init_model(cfg: ModelConfig, key) -> dict:
    if cfg.family == "xlstm":
        return xlstm.init_xlstm(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def forward(params, plan, segments=None, *, state=None,
            want_taps: bool = False, **inputs):
    """inputs: tokens / src_embeds / patch_embeds / patch_mask / enc_out.

    ``plan`` is an ``ExecutionPlan``; the legacy ``(cfg, segments)`` pair is
    accepted as a deprecation shim.
    """
    cfg, segments = _unpack_plan(plan, segments)
    if cfg.family == "xlstm":
        return xlstm.xlstm_forward(params, cfg, segments, states=state,
                                   want_taps=want_taps, **inputs)
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(params, cfg, segments, states=state,
                                     want_taps=want_taps, **inputs)
    if cfg.family == "encdec":
        return encdec.encdec_forward(params, cfg, segments, caches=state,
                                     want_taps=want_taps, **inputs)
    return transformer.lm_forward(params, cfg, segments, caches=state,
                                  want_taps=want_taps, **inputs)


def decode_state(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, as_specs: bool = False,
                 per_slot_len: bool = False,
                 kv_bits: Optional[int] = None):
    """per_slot_len=True allocates a (batch,) length vector instead of the
    scalar cursor, so a serving slot table can refill slots independently
    (transformer-family KV caches only).

    kv_bits 8/4 allocates the quantized packed cache layout (DESIGN.md §8)
    instead of fp K/V rows (transformer-family caches only); the default
    (None) follows ``cfg.kv_bits`` so the config knob means the same thing
    to every caller.

    Serving callers should not pick the dtype here: build an
    ``ExecutionPlan`` and use ``plan.decode_state(...)`` so engine, slot
    cache and prefill all share the plan's ONE decode dtype.
    """
    from ..deploy.plan import validate_cache_layout
    kv_bits = cfg.kv_bits if kv_bits is None else kv_bits
    validate_cache_layout(cfg, per_slot_len=per_slot_len, kv_bits=kv_bits)
    if cfg.family == "xlstm":
        return xlstm.xlstm_states(cfg, batch, as_specs=as_specs)
    if cfg.family == "hybrid":
        return hybrid.hybrid_states(cfg, batch, max_len, dtype, as_specs)
    if cfg.family == "encdec":
        L = cfg.dec_layers
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
            lambda s, d: jnp.zeros(s, d))
        return {"k": mk((L, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
                "v": mk((L, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype),
                "len": mk((), jnp.int32)}
    return transformer.lm_caches(cfg, batch, max_len, dtype, as_specs,
                                 per_slot_len=per_slot_len, kv_bits=kv_bits)


def decode_extra_inputs(cfg: ModelConfig, batch: int, src_len: int,
                        dtype=jnp.bfloat16, as_specs: bool = False) -> dict:
    """Family-specific extra decode inputs (enc-dec needs encoder output)."""
    if cfg.family == "encdec":
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
            lambda s, d: jnp.zeros(s, d))
        return {"enc_out": mk((batch, src_len, cfg.d_model), dtype)}
    return {}
