"""Decoder-only transformer LM (dense / MoE / VLM) and BERT-style encoder.

Layer stacks are ``lax.scan`` over stacked parameters (leading dim = layers)
for compile-time economy at 32-80 layers. The MKQ mixed-precision policy
(int4 from the last layer backwards, int8 elsewhere) yields CONTIGUOUS
bit-segments, so the stack is executed as one scan per segment with a static
``QuantSpec`` — no per-step branching on bit width.

MoE uses grouped dense one-hot dispatch (GShard/MaxText style): deterministic
shapes, GSPMD-friendly; the group axis shards with the batch. The dispatch
einsum FLOP overhead is analyzed (and attacked) in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.policy import QuantPolicy
from .attention import attention_block, init_attention
from .layers import (QuantSpec, act_fn, init_linear, init_norm, layernorm,
                     qlinear, rmsnorm)

# ------------------------------------------------------------------ policy → segments

def segments_from_policy(policy: QuantPolicy, use_pallas: bool = False,
                         fuse_epilogue: bool = False,
                         act_bits: int | None = None
                         ) -> list[tuple[int, int, QuantSpec]]:
    """Contiguous (start, end, QuantSpec) runs of equal bit-width.

    Low-level resolver: callers should build a
    ``repro.deploy.ExecutionPlan`` (DESIGN.md §9), which lands here with the
    kernel-selection flags resolved from its backend. ``act_bits`` is the
    plan-level activation override (DESIGN.md §13): applied to every
    quantized layer, so it can never merge or split the policy's segment
    boundaries (a layer's a_bits stays a pure function of its w_bits)."""
    segs: list[tuple[int, int, QuantSpec]] = []
    for l in range(policy.num_layers):
        wb, ab = policy.weight_bits(l) or 0, policy.act_bits(l) or 0
        if act_bits is not None and wb:
            ab = act_bits
        spec = QuantSpec(mode=policy.mode, w_bits=wb, a_bits=ab,
                         grad_mode=policy.grad_mode, use_pallas=use_pallas,
                         fuse_epilogue=fuse_epilogue)
        if segs and segs[-1][2] == spec:
            segs[-1] = (segs[-1][0], l + 1, spec)
        else:
            segs.append((l, l + 1, spec))
    return segs


def default_segments(num_layers: int) -> list[tuple[int, int, QuantSpec]]:
    return [(0, num_layers, QuantSpec())]


def _slice_stack(tree, start: int, end: int):
    return jax.tree.map(lambda a: a[start:end], tree)


def _to_cache(x, dtype):
    """Cast new-token k/v into the cache dtype; int8 caches quantize with the
    static KV scale (models/attention.py)."""
    import jax.numpy as _jnp
    if dtype == _jnp.int8:
        from .attention import KV_QUANT_SCALE
        return _jnp.clip(_jnp.round(x.astype(_jnp.float32) / KV_QUANT_SCALE),
                         -127, 127).astype(_jnp.int8)
    return x.astype(dtype)


def scan_layers(body, carry, xs):
    """lax.scan, or an eager python loop during calibration (so activation
    stats can be collected per layer — core/calibration.py)."""
    from ..core import calibration
    if not calibration.active():
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        stacked = None
    return carry, stacked


# ------------------------------------------------------------------ norms

def _norm(x, p, kind):
    return rmsnorm(x, p["scale"]) if kind == "rms" else layernorm(
        x, p["scale"], p["bias"])


# ------------------------------------------------------------------ FFN

def init_ffn(key, cfg: ModelConfig, stacked: int | None) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        if cfg.fused_proj:  # fused gate-up: one matmul, one bwd-dx psum
            return {"w13": init_linear(ks[0], d, 2 * f, False, stacked),
                    "w2": init_linear(ks[2], f, d, False, stacked)}
        return {"w1": init_linear(ks[0], d, f, False, stacked),
                "w3": init_linear(ks[1], d, f, False, stacked),
                "w2": init_linear(ks[2], f, d, False, stacked)}
    return {"w1": init_linear(ks[0], d, f, True, stacked),
            "w2": init_linear(ks[1], f, d, True, stacked)}


def ffn_apply(x, p, cfg: ModelConfig, spec: QuantSpec):
    if "w13" in p:
        h13 = qlinear(x, p["w13"], spec)
        h1, h3 = jnp.split(h13, 2, axis=-1)
        h = jax.nn.silu(h1.astype(jnp.float32)).astype(x.dtype) * h3
    elif cfg.act == "swiglu":
        h = jax.nn.silu(qlinear(x, p["w1"], spec).astype(jnp.float32)).astype(x.dtype)
        h = h * qlinear(x, p["w3"], spec)
    else:
        # non-gated FFN: the activation can ride the int4 kernel's fused
        # dequant+bias+GELU epilogue (one HBM round-trip instead of three)
        fused = (spec.mode == "int" and spec.use_pallas and spec.fuse_epilogue
                 and spec.w_bits == 4 and cfg.act in ("gelu", "relu"))
        h1 = qlinear(x, p["w1"], spec, act=cfg.act if fused else None)
        h = h1 if fused else act_fn(cfg.act)(h1)
    return qlinear(h, p["w2"], spec)


# ------------------------------------------------------------------ MoE

def _init_expert_linear(key, e: int, k: int, n: int, stacked: int | None) -> dict:
    shp = lambda *s: (stacked, *s) if stacked is not None else s
    return {"w": jax.random.normal(key, shp(e, k, n)) * 0.02,
            "s_w": jnp.ones(shp(e, 1, n), jnp.float32),
            "s_a": jnp.ones(shp(e, 1, 1), jnp.float32)}


def init_moe(key, cfg: ModelConfig, stacked: int | None) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 6)
    shp = lambda *s: (stacked, *s) if stacked is not None else s
    std = 0.02
    p = {
        "router": jax.random.normal(ks[0], shp(d, e), jnp.float32) * std,
        "w1": _init_expert_linear(ks[1], e, d, f, stacked),
        "w3": _init_expert_linear(ks[2], e, d, f, stacked),
        "w2": _init_expert_linear(ks[3], e, f, d, stacked),
    }
    if cfg.shared_expert_d_ff:
        sub = dataclasses.replace(cfg, d_ff=cfg.shared_expert_d_ff)
        p["shared"] = init_ffn(ks[4], sub, stacked)
        p["shared_gate"] = jax.random.normal(ks[5], shp(d, 1), jnp.float32) * std
    return p


def _expert_matmul(x_ecd, p: dict, spec: QuantSpec):
    """x: (E, C, K) @ w: (E, K, N) with per-expert quantization."""
    from ..core import calibration
    from ..core.packing import unpack_int4
    from ..core.quantizer import fake_quant, quantize_to_int
    if calibration.active():
        calibration.record_input(x_ecd, per_axis0=True)
    if spec.mode == "int":
        w8 = unpack_int4(p["wq"], axis=-2) if spec.w_bits == 4 else p["wq"]
        k = x_ecd.shape[-1]
        if w8.shape[-2] != k:
            w8 = jax.lax.slice_in_dim(w8, 0, k, axis=-2)
        if spec.a_bits == 0:  # fp-activation fallback (DESIGN.md §13)
            w = (w8.astype(jnp.float32) * p["s_w"]).astype(x_ecd.dtype)
            return jnp.einsum("eck,ekn->ecn", x_ecd, w)
        x8 = quantize_to_int(x_ecd, p["s_a"], spec.a_bits)
        acc = jnp.einsum("eck,ekn->ecn", x8, w8,
                         preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * (p["s_a"] * p["s_w"])).astype(x_ecd.dtype)
    w = p["w"]
    if spec.mode == "fake" and spec.enabled:
        w = fake_quant(w, p["s_w"], spec.w_bits, spec.grad_mode)
        if spec.a_bits:
            x_ecd = fake_quant(x_ecd, p["s_a"], spec.a_bits, spec.grad_mode)
    return jnp.einsum("eck,ekn->ecn", x_ecd, w.astype(x_ecd.dtype))


def moe_apply_sorted(x, p, cfg: ModelConfig, spec: QuantSpec):
    """Sort-based dispatch (SS Perf / DESIGN SS6b): argsort tokens by expert,
    gather into (E, C, d) slots, run experts, scatter-add back.

    Replaces the dense one-hot dispatch/combine einsums — whose FLOPs scale
    with tokens x capacity and dominate the MoE cells' compiled compute
    (useful ratio 0.03-0.26 in the baseline roofline) — with gathers that
    cost bytes, not MXU FLOPs. Equivalent to the dense path whenever no
    expert overflows capacity (test_moe_sorted_matches_dense); under
    overflow the two drop different tokens (priority order differs).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)
    C = max(1, int(T * K * cfg.capacity_factor / E))

    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                       # (T,E)
    top_vals, top_idx = jax.lax.top_k(gates, K)                   # (T,K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    e_flat = top_idx.reshape(-1)                                  # (T*K,)
    g_flat = top_vals.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = order // K
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_in_e = jnp.arange(T * K) - seg_start[e_sorted]
    keep = pos_in_e < C
    slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)        # drop -> pad

    # gather tokens into expert slots (one extra pad row)
    src = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(tok_sorted + 1)
    valid = (src > 0)[:E * C]
    xe = jnp.where(valid[:, None], xf[jnp.maximum(src[:E * C] - 1, 0)], 0.0)
    xe = xe.reshape(E, C, d)

    h1 = _expert_matmul(xe, p["w1"], spec)
    h3 = _expert_matmul(xe, p["w3"], spec)
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(x.dtype) * h3
    ye = _expert_matmul(h, p["w2"], spec).reshape(E * C, d)

    # scatter-add weighted expert outputs back to tokens
    y_rows = jnp.where(keep[:, None],
                       ye[jnp.clip(slot, 0, E * C - 1)]
                       * g_flat[order][:, None].astype(ye.dtype), 0.0)
    out = jnp.zeros((T, d), ye.dtype).at[tok_sorted].add(y_rows)

    if "shared" in p:
        gate = jax.nn.sigmoid(xf.astype(jnp.float32)
                              @ p["shared_gate"]).astype(x.dtype)
        out = out + gate * ffn_apply(
            xf, p["shared"],
            dataclasses.replace(cfg, d_ff=cfg.shared_expert_d_ff), spec)

    frac = jnp.mean(jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32), 0)
    prob = jnp.mean(gates, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac * prob)
    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_apply(x, p, cfg: ModelConfig, spec: QuantSpec):
    """Grouped dense dispatch. x: (B, S, d) -> (out, aux_loss)."""
    if cfg.moe_impl == "sorted":
        return moe_apply_sorted(x, p, cfg, spec)
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    G = max(1, (B * S) // cfg.moe_group_size)
    xg = x.reshape(G, -1, d)
    Sg = xg.shape[1]
    C = max(1, int(Sg * K * cfg.capacity_factor / E))

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                      # (G,Sg,E) fp32
    top_vals, top_idx = jax.lax.top_k(gates, K)                  # (G,Sg,K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, Sg, E, C), x.dtype)
    combine = jnp.zeros((G, Sg, E, C), jnp.float32)
    for j in range(K):  # K is small (4/8); unrolled
        m_j = jax.nn.one_hot(top_idx[..., j], E, dtype=jnp.float32)   # (G,Sg,E)
        pos = jnp.cumsum(m_j, axis=1) - 1.0 + counts
        keep = (pos < C) * m_j
        counts = counts + m_j.sum(axis=1, keepdims=True)
        oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        d_j = keep[..., None] * oh                                    # (G,Sg,E,C)
        dispatch = dispatch + d_j.astype(x.dtype)
        combine = combine + d_j * top_vals[..., j, None, None]

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, x.reshape(G, Sg, d))  # (G,E,C,d)
    xe = xe.transpose(1, 0, 2, 3).reshape(E, G * C, d)
    h1 = _expert_matmul(xe, p["w1"], spec)
    h3 = _expert_matmul(xe, p["w3"], spec)
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(x.dtype) * h3
    ye = _expert_matmul(h, p["w2"], spec)
    ye = ye.reshape(E, G, C, d).transpose(1, 0, 2, 3)                 # (G,E,C,d)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    if "shared" in p:
        gate = jax.nn.sigmoid(x.astype(jnp.float32) @ p["shared_gate"]).astype(x.dtype)
        out = out.reshape(B, S, d) + gate * ffn_apply(
            x, p["shared"], dataclasses.replace(cfg, d_ff=cfg.shared_expert_d_ff), spec)
        out = out.reshape(G, Sg, d)

    # Switch-style load-balance aux loss.
    frac = jnp.mean(jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    prob = jnp.mean(gates, axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(frac * prob)
    return out.reshape(B, S, d), aux


# ------------------------------------------------------------------ block / stack

def init_block(key, cfg: ModelConfig, stacked: int | None) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm, stacked),
         "attn": init_attention(ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                cfg.hd, cfg.qkv_bias, cfg.out_bias, stacked,
                                fused=cfg.fused_proj),
         "ln2": init_norm(ks[2], cfg.d_model, cfg.norm, stacked)}
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[3], cfg, stacked)
    else:
        p["ffn"] = init_ffn(ks[3], cfg, stacked)
    return p


def block_apply(x, p, cfg: ModelConfig, spec: QuantSpec, *,
                cache: Optional[dict] = None, want_taps: bool = False,
                positions=None, kv_len=None):
    pre = cfg.norm == "rms" or not cfg.learned_pos  # BERT uses post-LN
    chunk = cfg.attn_chunk if x.shape[1] > cfg.attn_chunk_threshold else 0
    aux = jnp.zeros((), jnp.float32)

    ssa = (tuple(cfg.dp_axes), "model") if cfg.attn_seq_shard else None

    def attn_fn(h):
        return attention_block(
            h, p["attn"], n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, hd=cfg.hd,
            spec=spec, causal=cfg.causal, rope=cfg.rope, rope_theta=cfg.rope_theta,
            positions=positions, cache=cache, chunk=chunk,
            seq_shard_axes=ssa, kv_len=kv_len, want_taps=want_taps)

    if pre:
        a, new_cache, taps = attn_fn(_norm(x, p["ln1"], cfg.norm))
        x = x + a
        h = _norm(x, p["ln2"], cfg.norm)
        if cfg.family == "moe":
            f, aux = moe_apply(h, p["moe"], cfg, spec)
        else:
            f = ffn_apply(h, p["ffn"], cfg, spec)
        x = x + f
    else:  # post-LN (BERT)
        a, new_cache, taps = attn_fn(x)
        x = _norm(x + a, p["ln1"], cfg.norm)
        if cfg.family == "moe":
            f, aux = moe_apply(x, p["moe"], cfg, spec)
        else:
            f = ffn_apply(x, p["ffn"], cfg, spec)
        x = _norm(x + f, p["ln2"], cfg.norm)
    return x, new_cache, taps, aux


# ------------------------------------------------------------------ full model

def init_lm(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    L = cfg.num_layers
    V = cfg.padded_vocab
    params = {
        "embed": jax.random.normal(ks[0], (V, cfg.d_model)) * 0.02,
        "layers": init_block(ks[1], cfg, stacked=L),
        "final_norm": init_norm(ks[2], cfg.d_model, cfg.norm),
    }
    if cfg.learned_pos:
        params["pos_embed"] = jax.random.normal(
            jax.random.fold_in(ks[0], 1), (8192, cfg.d_model)) * 0.02
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            ks[3], (cfg.d_model, V)) * 0.02
    return params


def _embed(params, cfg: ModelConfig, tokens=None, src_embeds=None,
           patch_embeds=None, patch_mask=None, offset=0):
    if src_embeds is not None:
        x = src_embeds
    else:
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        if patch_embeds is not None:
            B, S, d = x.shape
            npatch = patch_embeds.shape[1]
            # place patch embeddings at masked positions (anyres stub: first
            # `num_patches` masked slots correspond to patch rows in order)
            idx = jnp.cumsum(patch_mask.astype(jnp.int32), axis=1) - 1
            idx = jnp.clip(idx, 0, npatch - 1)
            gathered = jnp.take_along_axis(
                patch_embeds, idx[..., None].repeat(d, -1), axis=1)
            x = jnp.where(patch_mask[..., None], gathered.astype(x.dtype), x)
    if cfg.learned_pos:
        S = x.shape[1]
        x = x + params["pos_embed"][offset:offset + S][None].astype(x.dtype)
    return x


def lm_forward(params, cfg: ModelConfig, segments, *, tokens=None,
               src_embeds=None, patch_embeds=None, patch_mask=None,
               caches=None, want_taps: bool = False):
    """Returns (logits, new_caches, taps, aux_loss).

    caches: stacked per-layer KV caches {'k': (L,B,Smax,Hkv,hd), ...} or None.
    """
    x = _embed(params, cfg, tokens, src_embeds, patch_embeds, patch_mask,
               offset=0)
    layers = params["layers"]
    # Deployed int mode: layers arrive as a per-segment list (packed weights
    # can't live in one stacked array across bit-width segments).
    presliced = isinstance(layers, (list, tuple))
    aux_total = jnp.zeros((), jnp.float32)
    taps = None

    def write_new_kv(cs, idx, new_kv):
        """insert (B, Sq, Hkv, dh) new-token k/v at [layer=idx, :, len] —
        a one-token write instead of a full-cache copy per layer.

        With per-slot lengths (cs['len'] shaped (B,), serving slot table)
        each slot's tokens scatter to its own cursor; out-of-bounds writes
        (idle slots past max_len) are dropped by the scatter.

        Quantized caches ('k_q' layout, DESIGN.md §8) quantize-on-append:
        the fp k/v rows become integer codes plus one scale per (token,
        head) row, written with the same per-slot scatter — a token's scale
        never aliases another token's, so slot isolation is unaffected."""
        k_new, v_new = new_kv
        lens = jnp.asarray(cs["len"])
        if "k_q" in cs:
            from ..kernels.kv_pack import quantize_kv
            bits = 4 if cs["k_q"].dtype == jnp.uint8 else 8
            kq, ks = quantize_kv(k_new, bits)     # (B,Sq,Hkv,*), (B,Sq,Hkv)
            vq, vs = quantize_kv(v_new, bits)
            rows = {"k_q": kq, "v_q": vq, "k_scale": ks, "v_scale": vs}
        else:
            rows = {"k": _to_cache(k_new, cs["k"].dtype),
                    "v": _to_cache(v_new, cs["v"].dtype)}

        B, Sq = k_new.shape[0], k_new.shape[1]
        if lens.ndim:
            r = jnp.arange(B)[:, None]
            c = lens[:, None] + jnp.arange(Sq)[None, :]
            write = lambda buf, val: buf.at[idx, r, c].set(val, mode="drop")
        else:
            # start index (layer, batch=0, cursor, 0...) padded to buf rank
            write = lambda buf, val: jax.lax.dynamic_update_slice(
                buf, val[None],
                (idx, 0, cs["len"]) + (0,) * (buf.ndim - 3))
        out = {key: write(cs[key], val) for key, val in rows.items()}
        out["len"] = cs["len"]
        return out

    def layer_cache(cs, idx):
        """Per-layer slice of the stacked cache; works for the fp {'k','v'}
        and the quantized {'k_q','v_q','k_scale','v_scale'} layouts alike."""
        return {key: (val if key == "len" else
                      jax.lax.dynamic_index_in_dim(val, idx, 0, False))
                for key, val in cs.items()}

    def make_body(spec, with_cache):
        def body(carry, xs):
            if with_cache:
                # caches ride the carry: read the layer's slice, write only
                # the new token (XLA aliases the donated cache buffer).
                h, cs = carry
                lp, idx = xs
                cache_l = layer_cache(cs, idx)
                h2, nc, _, aux = block_apply(h, lp, cfg, spec, cache=cache_l)
                return (h2, write_new_kv(cs, idx, nc)), aux
            h = carry
            lp = xs
            h2, _, _, aux = block_apply(h, lp, cfg, spec)
            return h2, aux
        return body

    for si, (start, end, spec) in enumerate(segments):
        is_last_seg = si == len(segments) - 1
        n_scan = end - start - (1 if (want_taps and is_last_seg) else 0)
        seg_full = layers[si] if presliced else _slice_stack(layers, start, end)
        seg_layers = _slice_stack(seg_full, 0, n_scan)
        body = make_body(spec, caches is not None)
        if cfg.remat:
            body = jax.checkpoint(body)
        if n_scan > 0:
            if caches is not None:
                idxs = jnp.arange(start, start + n_scan)
                (x, caches), auxs = jax.lax.scan(
                    body, (x, caches), (seg_layers, idxs))
            else:
                x, auxs = scan_layers(body, x, seg_layers)
            aux_total = aux_total + jnp.sum(auxs)
        if want_taps and is_last_seg:
            lp = jax.tree.map(lambda a: a[-1], seg_full)
            cache_l = None
            if caches is not None:
                cache_l = layer_cache(caches, jnp.int32(end - 1))
            x, nc, taps, aux = block_apply(x, lp, cfg, spec, cache=cache_l,
                                           want_taps=True)
            aux_total = aux_total + aux
            if caches is not None:
                caches = write_new_kv(caches, end - 1, nc)

    new_caches = None
    if caches is not None:
        new_caches = {**caches, "len": caches["len"] + x.shape[1]}

    x = _norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head")
    if head is None:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ head.astype(x.dtype)
    logits = mask_padded_vocab(logits, cfg)
    return logits, new_caches, taps, aux_total


def lm_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
              as_specs: bool = False, per_slot_len: bool = False,
              kv_bits: int = 16):
    """kv_bits 16: fp {'k','v','len'}. kv_bits 8/4: the packed quantized
    layout {'k_q','v_q','k_scale','v_scale','len'} (DESIGN.md §8) — integer
    codes (int4 nibble-packed along head_dim) plus per-(token, head) f32
    scales; ~4x/~7x fewer cache bytes than f32 K/V rows."""
    L, Hkv = cfg.num_layers, cfg.num_kv_heads
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
        lambda s, d: jnp.zeros(s, d))
    len_shape = (batch,) if per_slot_len else ()
    if kv_bits in (8, 4):
        from ..kernels.kv_pack import kv_code_dtype, kv_code_shape
        dhp = kv_code_shape(cfg.hd, kv_bits)
        cdt = kv_code_dtype(kv_bits)
        return {"k_q": mk((L, batch, max_len, Hkv, dhp), cdt),
                "v_q": mk((L, batch, max_len, Hkv, dhp), cdt),
                "k_scale": mk((L, batch, max_len, Hkv), jnp.float32),
                "v_scale": mk((L, batch, max_len, Hkv), jnp.float32),
                "len": mk(len_shape, jnp.int32)}
    if kv_bits != 16:
        raise ValueError(f"kv_bits must be 16, 8 or 4, got {kv_bits}")
    return {"k": mk((L, batch, max_len, Hkv, cfg.hd), dtype),
            "v": mk((L, batch, max_len, Hkv, cfg.hd), dtype),
            "len": mk(len_shape, jnp.int32)}


def mask_padded_vocab(logits, cfg: ModelConfig):
    """-inf the vocab-padding logits (embedding rows padded for TP)."""
    V = cfg.padded_vocab
    if V == cfg.vocab_size:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
    return jnp.where(ids < cfg.vocab_size, logits,
                     jnp.asarray(-1e9, logits.dtype))


def lm_loss(logits, labels, ignore_id: int = -1):
    """Next-token CE in fp32; labels already shifted by the data pipeline."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].clip(0), -1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
