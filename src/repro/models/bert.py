"""BERT/TinyBERT encoder + classification head — the paper's own models.

TinyBERT4 (Jiao et al. 2019): L=4, d_h=312, d_i=1200, 12 heads — the student
quantized in Table 1. BERT-base is available as a (deeper) teacher. Built on
the shared transformer stack with post-LN, learned positions, GELU FFN,
bidirectional attention.

``bert_encode`` / ``bert_classify_logits`` route through an
``ExecutionPlan`` (DESIGN.md §9/§14) like every other family — the legacy
``(params, cfg, segments, tokens)`` positional form is kept as a deprecation
shim, mirroring ``models.api.forward``. Both accept per-row ``lengths``:
padded key positions are masked out of the bidirectional attention, so a
bucket-padded batch row is bit-identical to the unpadded forward — the
property the prefill-only serving path (serving/encoder.py) is built on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .transformer import init_lm, scan_layers


def tinybert_config(num_classes: int = 2, layers=4, d=312, heads=12,
                    d_ff=1200, vocab=30522, name="tinybert4") -> ModelConfig:
    return ModelConfig(
        name=name, family="bert", num_layers=layers, d_model=d,
        num_heads=heads, num_kv_heads=heads, d_ff=d_ff, vocab_size=vocab,
        qkv_bias=True, out_bias=True, norm="ln", act="gelu", rope=False,
        causal=False, learned_pos=True, dtype="float32", remat=False)


def init_bert_classifier(cfg: ModelConfig, num_classes: int, key) -> dict:
    ks = jax.random.split(key, 3)
    params = init_lm(cfg, ks[0])
    params.pop("lm_head", None)  # classification head instead
    params["pooler"] = {"w": jax.random.normal(ks[1], (cfg.d_model, cfg.d_model)) * 0.02,
                        "b": jnp.zeros((cfg.d_model,))}
    params["classifier"] = {"w": jax.random.normal(ks[2], (cfg.d_model, num_classes)) * 0.02,
                            "b": jnp.zeros((num_classes,))}
    return params


def _unpack(plan, segments, tokens):
    """(plan, tokens) or legacy (cfg, segments, tokens) → (cfg, segs, toks).

    New form: ``bert_encode(params, plan, tokens)`` — the third positional
    slot carries the tokens. Legacy form: ``bert_encode(params, cfg,
    segments, tokens)`` (deprecation shim, same pattern as api.forward)."""
    if isinstance(plan, ModelConfig):
        if tokens is None:
            raise TypeError(
                "bert forward with a raw ModelConfig needs (cfg, segments, "
                "tokens); build an ExecutionPlan instead "
                "(repro.deploy.ExecutionPlan.build)")
        return plan, segments, tokens
    return plan.cfg, plan.segments, (segments if tokens is None else tokens)


def bert_encode(params, plan, segments=None, tokens=None,
                want_taps: bool = False, *, lengths=None):
    """Final hidden states (B, S, d) + taps, via the shared stack.

    ``lengths`` (B,) masks key positions ``>= lengths[b]`` out of every
    attention layer — rows padded to a common bucket stay bit-identical to
    their unpadded forward (bidirectional attention would otherwise attend
    the zero tail). Padded QUERY positions still produce (garbage) outputs;
    callers read real positions only (the CLS pool reads position 0).
    """
    from .transformer import _embed, _norm, _slice_stack, block_apply

    cfg, segments, tokens = _unpack(plan, segments, tokens)
    kv_len = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    x = _embed(params, cfg, tokens)
    layers = params["layers"]
    presliced = isinstance(layers, (list, tuple))
    taps = None
    for si, (start, end, spec) in enumerate(segments):
        is_last = si == len(segments) - 1
        n_scan = end - start - (1 if (want_taps and is_last) else 0)
        seg_full = layers[si] if presliced else _slice_stack(layers, start, end)
        seg = _slice_stack(seg_full, 0, n_scan)

        def body(carry, lp):
            h, _, _, _ = block_apply(carry, lp, cfg, spec, kv_len=kv_len)
            return h, None

        if n_scan > 0:
            x, _ = scan_layers(body, x, seg)
        if want_taps and is_last:
            lp = jax.tree.map(lambda a: a[-1], seg_full)
            x, _, taps, _ = block_apply(x, lp, cfg, spec, want_taps=True,
                                        kv_len=kv_len)
    x = _norm(x, params["final_norm"], cfg.norm)
    return x, taps


def bert_pool(params, h):
    """CLS pooling: tanh projection of position 0 → (B, d) embedding."""
    return jnp.tanh(h[:, 0].astype(jnp.float32) @ params["pooler"]["w"]
                    + params["pooler"]["b"])


def bert_classify_logits(params, plan, segments=None, tokens=None,
                         want_taps: bool = False, *, lengths=None):
    h, taps = bert_encode(params, plan, segments, tokens, want_taps,
                          lengths=lengths)
    pooled = bert_pool(params, h)
    logits = pooled @ params["classifier"]["w"] + params["classifier"]["b"]
    return logits, taps


def classification_loss(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    return jnp.mean(logz - gold)
