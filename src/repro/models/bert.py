"""BERT/TinyBERT encoder + classification head — the paper's own models.

TinyBERT4 (Jiao et al. 2019): L=4, d_h=312, d_i=1200, 12 heads — the student
quantized in Table 1. BERT-base is available as a (deeper) teacher. Built on
the shared transformer stack with post-LN, learned positions, GELU FFN,
bidirectional attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .transformer import init_lm, scan_layers


def tinybert_config(num_classes: int = 2, layers=4, d=312, heads=12,
                    d_ff=1200, vocab=30522, name="tinybert4") -> ModelConfig:
    return ModelConfig(
        name=name, family="bert", num_layers=layers, d_model=d,
        num_heads=heads, num_kv_heads=heads, d_ff=d_ff, vocab_size=vocab,
        qkv_bias=True, out_bias=True, norm="ln", act="gelu", rope=False,
        causal=False, learned_pos=True, dtype="float32", remat=False)


def init_bert_classifier(cfg: ModelConfig, num_classes: int, key) -> dict:
    ks = jax.random.split(key, 3)
    params = init_lm(cfg, ks[0])
    params.pop("lm_head", None)  # classification head instead
    params["pooler"] = {"w": jax.random.normal(ks[1], (cfg.d_model, cfg.d_model)) * 0.02,
                        "b": jnp.zeros((cfg.d_model,))}
    params["classifier"] = {"w": jax.random.normal(ks[2], (cfg.d_model, num_classes)) * 0.02,
                            "b": jnp.zeros((num_classes,))}
    return params


def bert_encode(params, cfg: ModelConfig, segments, tokens,
                want_taps: bool = False):
    """Final hidden states (B, S, d) + taps, via the shared stack."""
    from .transformer import _embed, _norm, _slice_stack, block_apply

    x = _embed(params, cfg, tokens)
    layers = params["layers"]
    presliced = isinstance(layers, (list, tuple))
    taps = None
    for si, (start, end, spec) in enumerate(segments):
        is_last = si == len(segments) - 1
        n_scan = end - start - (1 if (want_taps and is_last) else 0)
        seg_full = layers[si] if presliced else _slice_stack(layers, start, end)
        seg = _slice_stack(seg_full, 0, n_scan)

        def body(carry, lp):
            h, _, _, _ = block_apply(carry, lp, cfg, spec)
            return h, None

        if n_scan > 0:
            x, _ = scan_layers(body, x, seg)
        if want_taps and is_last:
            lp = jax.tree.map(lambda a: a[-1], seg_full)
            x, _, taps, _ = block_apply(x, lp, cfg, spec, want_taps=True)
    x = _norm(x, params["final_norm"], cfg.norm)
    return x, taps


def bert_classify_logits(params, cfg: ModelConfig, segments, tokens,
                         want_taps: bool = False):
    h, taps = bert_encode(params, cfg, segments, tokens, want_taps)
    pooled = jnp.tanh(h[:, 0].astype(jnp.float32) @ params["pooler"]["w"]
                      + params["pooler"]["b"])
    logits = pooled @ params["classifier"]["w"] + params["classifier"]["b"]
    return logits, taps


def classification_loss(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    return jnp.mean(logz - gold)
