"""Mamba2 (SSD) blocks and the Zamba2 hybrid stack.

Training/prefill uses the chunked SSD algorithm (Mamba2 paper, minimal form):
intra-chunk quadratic term + inter-chunk state recurrence via ``lax.scan`` over
chunks. Decode is the O(1) per-token recurrence on state (B, H, P, N).

Zamba2 (arXiv:2411.15242): a Mamba2 backbone where ONE shared
attention+FFN block (single weight set) is invoked every ``attn_every``-th
layer. We structure the stack as scan-over-groups; each group = (attn_every-1)
Mamba2 layers (stacked params) + one invocation of the shared block. Each
invocation keeps its own KV cache.

Quantization (MKQ): in/out projections and shared-block matmuls route through
``qlinear``; SSM internals (gates, scan, conv) stay fp32 — the same structural
rule as LayerNorm/softmax in the paper. Attention distill applies only to the
shared block; Mamba2 layers use hidden-state distill (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import QuantSpec, init_linear, init_norm, qlinear, rmsnorm

CONV_K = 4


# ------------------------------------------------------------------ SSD core

def _segsum(x):
    """log-space segment sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    d = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """Chunked SSD. x:(B,S,H,P) dt:(B,S,H) a_log:(H,) b,c:(B,S,N) -> y, final_state.

    Single B/C group shared across heads (n_groups=1).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = chunk
    nc = S // Q
    assert S % Q == 0, (S, Q)
    xd = x * dt[..., None]                                  # dt-weighted input
    dA = dt * (-jnp.exp(a_log))[None, None, :]              # (B,S,H) <= 0
    # chunked views
    xc = xd.reshape(B, nc, Q, H, P)
    bc = b.reshape(B, nc, Q, N)
    cc = c.reshape(B, nc, Q, N)
    dAc = dA.reshape(B, nc, Q, H)
    cum = jnp.cumsum(dAc, axis=2)                           # (B,nc,Q,H)

    # 1) intra-chunk (diagonal) term
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))         # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)          # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, L.astype(scores.dtype), xc)
    # 2) per-chunk input states
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bc, decay_states, xc)
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,N)
    # 4) state -> output within chunk
    state_decay = jnp.exp(cum)                              # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       cc, prev_states.astype(cc.dtype), state_decay)
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final_state


def ssd_decode_step(state, x, dt, a_log, b, c):
    """One-token recurrence. state:(B,H,P,N) x:(B,1,H,P) dt:(B,1,H) b,c:(B,1,N)."""
    dA = jnp.exp(dt[:, 0] * (-jnp.exp(a_log))[None, :])     # (B,H)
    xd = (x * dt[..., None])[:, 0]                          # (B,H,P)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xd.astype(jnp.float32), b[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state.astype(c.dtype), c[:, 0])
    return y[:, None], new_state


# ------------------------------------------------------------------ block

def init_mamba2_block(key, cfg: ModelConfig, stacked=None) -> dict:
    """z/x projections are separate weights (TP: column-sharded over 'model');
    the small B/C/dt projections stay replicated (DESIGN.md §4)."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    shp = lambda *s: (stacked, *s) if stacked is not None else s
    return {
        "norm": init_norm(ks[0], d, "rms", stacked),
        "in_z": init_linear(ks[1], d, di, False, stacked),
        "in_x": init_linear(ks[2], d, di, False, stacked),
        "in_bc": init_linear(ks[4], d, 2 * N, False, stacked),
        "in_dt": init_linear(ks[5], d, H, False, stacked),
        "conv_w": jax.random.normal(ks[3], shp(CONV_K, di + 2 * N)) * 0.1,
        "a_log": jnp.zeros(shp(H), jnp.float32),
        "dt_bias": jnp.zeros(shp(H), jnp.float32),
        "d_skip": jnp.ones(shp(H), jnp.float32),
        "ssm_norm": init_norm(ks[0], di, "rms", stacked),
        "out_proj": init_linear(ks[3], di, d, False, stacked),
    }


def _causal_conv(u, w, cache=None):
    """Depthwise causal conv. u:(B,S,C) w:(K,C); cache:(B,K-1,C) for decode."""
    if cache is not None:
        u_ext = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
        new_cache = u_ext[:, -(CONV_K - 1):]
    else:
        u_ext = jnp.pad(u, ((0, 0), (CONV_K - 1, 0), (0, 0)))
        new_cache = None
    S = u.shape[1]
    out = sum(u_ext[:, i:i + S] * w[i] for i in range(CONV_K))
    return out, new_cache


def mamba2_block(x, p, cfg: ModelConfig, spec: QuantSpec,
                 state: Optional[dict] = None):
    """Pre-norm residual Mamba2 block. state: {'ssm': (B,H,P,N), 'conv': (B,K-1,C)}."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    B_, S, _ = x.shape

    h = rmsnorm(x, p["norm"]["scale"])
    z = qlinear(h, p["in_z"], spec)
    xs = qlinear(h, p["in_x"], spec)
    bc = qlinear(h, p["in_bc"], spec)
    dt = qlinear(h, p["in_dt"], spec)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"], None if state is None else state["conv"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, b, c = jnp.split(conv_out, [di, di + N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)

    if state is None:
        y, _ = ssd_chunked(xs, dt, p["a_log"], b, c, cfg.ssm_chunk)
        new_state = None
    else:
        y, new_ssm = ssd_decode_step(state["ssm"], xs, dt, p["a_log"], b, c)
        new_state = {"ssm": new_ssm, "conv": new_conv}
    y = y.astype(x.dtype) + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["ssm_norm"]["scale"])
    return x + qlinear(y, p["out_proj"], spec), new_state


def mamba2_state_init(cfg: ModelConfig, batch: int, as_specs=False):
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if as_specs else (
        lambda s, d: jnp.zeros(s, d))
    return {"ssm": mk((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": mk((batch, CONV_K - 1, di + 2 * cfg.ssm_state), jnp.float32)}
