"""Mixed-precision quantization policy (paper §5.2/§5.3).

The paper quantizes from the LAST layer backwards into int4 (higher layers are
more robust), keeps the rest int8, and never quantizes the embedding;
LayerNorm / softmax / GELU stay fp32 (enforced structurally: only linear
matmuls go through quantized paths).

``QuantPolicy`` is pure data — models consume per-layer bit-vectors so the
policy composes with ``lax.scan`` over stacked layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = ["QuantPolicy"]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which layers get which bit-width.

    mode:        'none' (fp baseline) | 'fake' (QAT fake-quant) | 'int' (deployed)
    int4_layers: explicit layer indices quantized to 4 bits, or use last_k_int4.
    default_bits: bits for the remaining (non-int4) layers — 8 per the paper.
    grad_mode:   'mse' (MKQ-BERT) | 'ste' (KDLSQ baseline).
    act_bits_follow: activations use the same bits as the layer's weights
                 (paper: true 4-bit activations — unlike KDLSQ's int8 acts).
    """

    num_layers: int
    mode: str = "fake"
    int4_layers: Optional[Sequence[int]] = None
    last_k_int4: int = 0
    default_bits: int = 8
    grad_mode: str = "mse"
    act_bits_follow: bool = True
    act_bits_override: Optional[int] = None  # e.g. KDLSQ: weights 4-bit, acts 8-bit
    per_row_weight_scale: bool = True
    quant_embedding: bool = False  # paper: never

    def __post_init__(self):
        if self.mode not in ("none", "fake", "int"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.grad_mode not in ("mse", "ste"):
            raise ValueError(f"bad grad_mode {self.grad_mode!r}")

    def weight_bits(self, layer: int) -> Optional[int]:
        if self.mode == "none":
            return None
        if self.int4_layers is not None and layer in set(self.int4_layers):
            return 4
        if self.last_k_int4 and layer >= self.num_layers - self.last_k_int4:
            return 4
        return self.default_bits

    def act_bits(self, layer: int) -> Optional[int]:
        if self.mode == "none":
            return None
        if self.act_bits_override is not None:
            return self.act_bits_override
        wb = self.weight_bits(layer)
        return wb if self.act_bits_follow else self.default_bits

    def weight_bits_vector(self) -> np.ndarray:
        """Per-layer weight bits as an int array (0 = unquantized) for scan bodies."""
        return np.array(
            [self.weight_bits(l) or 0 for l in range(self.num_layers)], dtype=np.int32
        )

    def act_bits_vector(self) -> np.ndarray:
        return np.array(
            [self.act_bits(l) or 0 for l in range(self.num_layers)], dtype=np.int32
        )

    @classmethod
    def from_dict(cls, d: dict) -> "QuantPolicy":
        """Inverse of ``dataclasses.asdict`` after a JSON round trip (the
        DeployedModel artifact meta — DESIGN.md §9). Unknown keys are
        dropped so artifacts from a newer build still load."""
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        if d.get("int4_layers") is not None:
            d["int4_layers"] = tuple(d["int4_layers"])
        return cls(**d)

    def describe(self) -> str:
        i4 = [l for l in range(self.num_layers) if self.weight_bits(l) == 4]
        return (
            f"QuantPolicy(mode={self.mode}, grad={self.grad_mode}, "
            f"int4_layers={i4}, default={self.default_bits}b)"
        )
