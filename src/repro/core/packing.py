"""int4 nibble packing and deploy-time weight quantization.

Packed int4 layout (TPU adaptation, DESIGN.md §3): values live in the paper's
k=4 grid [-7, 8]; we store them biased by +7 into unsigned nibbles [0, 15],
two per byte along the CONTRACTING (K) axis:

    packed[k, n] = (code[2k, n] & 0xF) | (code[2k+1, n] << 4)

so a (K, N) int-code matrix becomes a (K/2, N) uint8 matrix — 8x fewer HBM
bytes than f32, 2x fewer than int8. The Pallas kernel unpacks nibbles in VMEM
and feeds the int8 MXU path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .quantizer import quantize_to_int

INT4_BIAS = 7  # maps [-7, 8] -> [0, 15]


def pack_int4(codes: jax.Array, axis: int = 0) -> jax.Array:
    """Pack int4 codes (int8 carrier, values in [-7, 8]) into uint8 nibbles.

    ``axis`` is the packing axis (must have even extent; pad beforehand).
    """
    axis = axis % codes.ndim
    if codes.shape[axis] % 2 != 0:
        raise ValueError(f"pack axis extent must be even, got {codes.shape[axis]}")
    biased = (codes.astype(jnp.int32) + INT4_BIAS).astype(jnp.uint8)
    lo = jax.lax.slice_in_dim(biased, 0, codes.shape[axis], stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(biased, 1, codes.shape[axis], stride=2, axis=axis)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns int8 codes in [-7, 8]."""
    axis = axis % packed.ndim
    lo = (packed & 0xF).astype(jnp.int8) - INT4_BIAS
    hi = (packed >> 4).astype(jnp.int8) - INT4_BIAS
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # (..., K/2, 2, ...)
    new_shape = list(packed.shape)
    new_shape[axis] = packed.shape[axis] * 2
    return stacked.reshape(new_shape)


def quantize_weight(
    w: jax.Array, s: jax.Array, bits: int, pack_axis: Optional[int] = -2
):
    """Quantize one weight for deployment. Returns (codes_or_packed, s).

    ``w`` is (..., K, N) with per-out-channel scales (..., 1, N) or scalar.
    bits=4 packs along K = axis -2 (pads K to even); bits=8 stores int8.
    Leading dims cover stacked layers and/or experts.
    """
    codes = quantize_to_int(w, s, bits)
    if bits == 4 and pack_axis is not None:
        axis = pack_axis % codes.ndim
        k = codes.shape[axis]
        if k % 2 != 0:
            pad = [(0, 0)] * codes.ndim
            pad[axis] = (0, 1)
            codes = jnp.pad(codes, pad)
        return pack_int4(codes, axis=axis), s
    return codes, s


def int4_packed_nbytes(shape: tuple[int, ...], axis: int = 0) -> int:
    n = 1
    for i, d in enumerate(shape):
        n *= (d + 1) // 2 if i == axis % len(shape) else d
    return n
