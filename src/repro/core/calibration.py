"""Calibration: initial quantization scales (paper §3.1, following Q8BERT).

* Weights: s = max|w| / l_max, per-tensor or per-row (per output channel).
* Activations: run ~200 forward batches, collect |a| statistics, and set
  s = (top-0.01% largest |a|)  / l_max  — i.e. the 99.99th percentile.

The activation collector is a deterministic reservoir: an exact percentile over
every activation of every batch would hold the whole stream; we keep a seeded
uniform subsample per batch plus the running max, and take the percentile over
the reservoir at finalize (max-clamped). Deterministic across runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .quantizer import qrange

__all__ = ["weight_scale", "ActCalibrator", "PERCENTILE_DEFAULT",
           "calibration_mode", "active", "record_input"]

PERCENTILE_DEFAULT = 99.99  # "top 0.01% largest value"

# --------------------------------------------------------------- hook machinery
# During calibration the model runs its EAGER layer-loop path (forwards swap
# lax.scan for a python loop) and every quantizable matmul reports its input's
# |a| percentile here, in deterministic call order. core.qat maps the stream
# back onto the s_a leaves via the per-family site order.
_COLLECTOR: Optional[list] = None


class calibration_mode:
    """Context manager enabling activation-stat collection."""

    def __init__(self, percentile: float = PERCENTILE_DEFAULT):
        self.percentile = percentile
        self.records: list[np.ndarray] = []

    def __enter__(self):
        global _COLLECTOR
        if _COLLECTOR is not None:
            raise RuntimeError("nested calibration_mode")
        _COLLECTOR = self
        return self

    def __exit__(self, *exc):
        global _COLLECTOR
        _COLLECTOR = None
        return False


def active() -> bool:
    return _COLLECTOR is not None


def record_input(x: jax.Array, per_axis0: bool = False) -> None:
    """Record percentile(|x|); per_axis0 keeps the leading (expert) axis."""
    if _COLLECTOR is None:
        return
    a = np.abs(np.asarray(jax.device_get(x), dtype=np.float32))
    if per_axis0:
        stat = np.percentile(a.reshape(a.shape[0], -1), _COLLECTOR.percentile,
                             axis=1)
    else:
        stat = np.percentile(a.reshape(-1), _COLLECTOR.percentile)
    _COLLECTOR.records.append(np.asarray(stat, np.float32))


def weight_scale(w: jax.Array, bits: int, axis: Optional[int] = None) -> jax.Array:
    """abs-max weight scale; ``axis`` is the kept (per-channel) axis, None=per-tensor."""
    _, qmax = qrange(bits)
    if axis is None:
        s = jnp.max(jnp.abs(w))
    else:
        axis = axis % w.ndim
        red = tuple(i for i in range(w.ndim) if i != axis)
        s = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    return jnp.maximum(s / qmax, 1e-8).astype(jnp.float32)


@dataclasses.dataclass
class ActCalibrator:
    """Streaming |activation| percentile estimator (one per quantized activation)."""

    percentile: float = PERCENTILE_DEFAULT
    samples_per_batch: int = 4096
    seed: int = 0

    def __post_init__(self):
        self._chunks: list[np.ndarray] = []
        self._absmax = 0.0
        self._step = 0

    def update(self, a: jax.Array) -> None:
        flat = np.abs(np.asarray(jax.device_get(a), dtype=np.float32).reshape(-1))
        self._absmax = max(self._absmax, float(flat.max(initial=0.0)))
        if flat.size > self.samples_per_batch:
            rng = np.random.default_rng(self.seed + self._step)
            flat = rng.choice(flat, size=self.samples_per_batch, replace=False)
        self._chunks.append(flat)
        self._step += 1

    def scale(self, bits: int) -> jax.Array:
        """Finalize: s = percentile(|a|) / l_max (clamped to running max)."""
        _, qmax = qrange(bits)
        if not self._chunks:
            return jnp.float32(1.0)
        sample = np.concatenate(self._chunks)
        p = float(np.percentile(sample, self.percentile))
        p = min(max(p, 1e-8), self._absmax if self._absmax > 0 else p)
        return jnp.float32(p / qmax)
