"""QAT pipeline: calibration -> fake-quant training -> int deployment.

Calibration (paper §3.1):
* weight scales: abs-max per output channel / l_max(bits-of-that-layer) —
  a pure tree transform (handles stacked layer/group/expert leading dims).
* activation scales: run N forward batches in ``calibration_mode`` (models
  swap lax.scan for an eager layer loop); every quantizable matmul reports
  percentile(|input|) in deterministic call order; the stream is folded back
  onto the ``s_a`` leaves by per-family site order.

Deployment: ``deploy_params`` splits stacked layers at segment boundaries and
replaces every fp weight with packed int4 / int8 codes (core.packing) so the
int inference path (and its Pallas kernels) can run.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import calibration
from .packing import quantize_weight
from .policy import QuantPolicy
from .quantizer import qrange

# ---------------------------------------------------------------- weight scales

_LINEAR_KEYS = ("w",)


def _is_linear(node) -> bool:
    return isinstance(node, dict) and "w" in node and "s_w" in node


def calibrate_weight_scales(params, bits_for_leaf: Callable[[tuple], np.ndarray]):
    """Set every linear's s_w = absmax_per_outchannel / l_max(bits).

    ``bits_for_leaf(shape_prefix)`` returns per-layer/group bits broadcastable
    to the leaf's leading (stacked) dims; scalar for unstacked.
    """
    def walk(node, prefix):
        if _is_linear(node):
            w = node["w"]
            s_w = node["s_w"]
            red = tuple(range(w.ndim))[-2:-1]  # K axis (second-to-last)
            absmax = jnp.max(jnp.abs(w), axis=red[0], keepdims=True)
            bits = np.asarray(bits_for_leaf(w.shape[:-2]), np.float32)
            # qrange-consistent l_max: 2^{k-1} for k<8, 127 for the int8 carrier
            qmax = jnp.asarray(np.where(bits >= 8, 2.0 ** (bits - 1) - 1,
                                        2.0 ** (bits - 1)))
            qmax = qmax.reshape(qmax.shape + (1,) * (absmax.ndim - qmax.ndim))
            new = dict(node)
            new["s_w"] = jnp.maximum(absmax / qmax, 1e-8).astype(s_w.dtype)
            return new
        if isinstance(node, dict):
            return {k: walk(v, prefix + (k,)) for k, v in node.items()}
        return node
    return walk(params, ())


def default_bits_fn(cfg: ModelConfig, policy: QuantPolicy):
    """Per-leaf bits resolver honoring stacked layer/group leading dims."""
    n_units = policy.num_layers
    per = {"xlstm": cfg.slstm_every, "hybrid": cfg.attn_every}.get(cfg.family)
    bits_vec = np.array([policy.weight_bits(l) or 32 for l in range(n_units)],
                        np.float32)

    def fn(shape_prefix: tuple) -> np.ndarray:
        if len(shape_prefix) == 0:
            return np.float32(policy.default_bits)
        L = shape_prefix[0]
        if per is not None:  # group-stacked (G, ...) or (G, per, ...)
            G = n_units // per
            if L == G:
                gbits = np.array([policy.weight_bits(g * per) or 32
                                  for g in range(G)], np.float32)
                out = gbits
            else:
                out = np.full(L, policy.default_bits, np.float32)
        elif L == n_units:
            out = bits_vec
        else:  # expert dim or other stacked dim: default bits
            out = np.full(L, policy.default_bits, np.float32)
        extra = shape_prefix[1:]
        return out.reshape((L,) + (1,) * len(extra))
    return fn


# ---------------------------------------------------------------- act scales

SITE_ORDERS = {
    # per-layer quantized-matmul input records, in model code order
    "attn": ["attn/wq", "attn/wk", "attn/wv", "attn/wo"],
    "ffn_swiglu": ["ffn/w1", "ffn/w3", "ffn/w2"],
    "ffn_gelu": ["ffn/w1", "ffn/w2"],
    "moe": ["moe/w1", "moe/w3", "moe/w2"],
}


def site_order(cfg: ModelConfig) -> list[str]:
    if cfg.family == "moe":
        sites = SITE_ORDERS["attn"] + SITE_ORDERS["moe"]
        if cfg.shared_expert_d_ff:
            sites = sites + ["moe/shared/w1", "moe/shared/w3", "moe/shared/w2"]
        return sites
    ffn = SITE_ORDERS["ffn_swiglu"] if cfg.act == "swiglu" else SITE_ORDERS["ffn_gelu"]
    return SITE_ORDERS["attn"] + ffn


def calibrate_act_scales(params, cfg: ModelConfig, policy: QuantPolicy,
                         forward_fn: Callable, batches: list[dict],
                         percentile: float = 99.99):
    """Transformer-family precise per-site calibration (dense/moe/vlm/bert).

    Non-transformer families use :func:`calibrate_act_scales_global`.
    """
    if cfg.family in ("xlstm", "hybrid", "encdec"):
        return calibrate_act_scales_global(params, cfg, policy, forward_fn,
                                           batches, percentile)
    sites = site_order(cfg)
    K = len(sites)
    L = cfg.num_layers
    with calibration.calibration_mode(percentile) as cm:
        for b in batches:
            forward_fn(params, b)
    rec = cm.records
    if len(rec) % (L * K) != 0:
        raise RuntimeError(
            f"calibration records {len(rec)} not divisible by L*K={L * K}; "
            "site order out of sync with model code")
    nb = len(rec) // (L * K)
    # aggregate max over batches -> per (layer, site)
    agg: list[list] = [[None] * K for _ in range(L)]
    i = 0
    for _ in range(nb):
        for l in range(L):
            for k in range(K):
                v = rec[i]
                i += 1
                agg[l][k] = v if agg[l][k] is None else np.maximum(agg[l][k], v)
    new_params = jax.tree.map(lambda a: a, params)  # shallow rebuild
    layers = dict(new_params["layers"])
    for k, site in enumerate(sites):
        parts = site.split("/")
        # navigate copy-on-write
        def set_in(d, parts, vals):
            d = dict(d)
            if len(parts) == 1:
                lin = dict(d[parts[0]])
                s_a = lin["s_a"]
                per_layer = np.stack([np.asarray(agg[l][k], np.float32)
                                      for l in range(L)])
                qmax = np.array([float(qrange(policy.act_bits(l) or 32)[1])
                                 for l in range(L)], np.float32)
                qmax = qmax.reshape((L,) + (1,) * (per_layer.ndim - 1))
                val = np.maximum(per_layer / qmax, 1e-8)
                lin["s_a"] = jnp.asarray(val.reshape(s_a.shape), s_a.dtype)
                d[parts[0]] = lin
                return d
            d[parts[0]] = set_in(d[parts[0]], parts[1:], vals)
            return d
        layers = set_in(layers, parts, None)
    new_params["layers"] = layers
    return new_params


def calibrate_act_scales_global(params, cfg, policy, forward_fn, batches,
                                percentile=99.99):
    """Fallback: one global percentile drives every s_a (documented approx)."""
    with calibration.calibration_mode(percentile) as cm:
        for b in batches:
            forward_fn(params, b)
    stat = float(max(np.max(r) for r in cm.records)) if cm.records else 1.0
    _, qmax = qrange(policy.default_bits)

    def walk(node):
        if _is_linear(node):
            new = dict(node)
            new["s_a"] = jnp.full_like(node["s_a"], max(stat / qmax, 1e-8))
            return new
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(params)


# ---------------------------------------------------------------- deployment

def _quantize_stack(tree, w_bits: int):
    """Replace every linear's 'w' with packed codes 'wq' (segment-sliced)."""
    def walk(node):
        if _is_linear(node):
            new = {k: v for k, v in node.items() if k != "w"}
            wq, _ = quantize_weight(node["w"], node["s_w"], w_bits)
            new["wq"] = wq
            return new
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def deploy_params(params, cfg: ModelConfig, segments) -> dict:
    """QAT params -> deployed int params (per-segment layer stacks).

    Low-level packer: ``repro.deploy.deploy(params, plan)`` wraps this into
    the saveable DeployedModel artifact (DESIGN.md §9).

    Dense/MoE/BERT/VLM: params['layers'] becomes a LIST of per-segment stacks.
    xlstm/hybrid: group stacks quantized per segment similarly; shared block
    (hybrid) quantized at the last segment's bits.
    """
    out = dict(params)
    if cfg.family in ("xlstm", "hybrid"):
        key = "mlstm" if cfg.family == "xlstm" else "mamba"
        stacks = []
        for (s, e, spec) in segments:
            seg = jax.tree.map(lambda a: a[s:e], params[key])
            stacks.append(_quantize_stack(seg, spec.w_bits)
                          if spec.enabled else seg)
        out[key] = stacks
        if cfg.family == "xlstm":
            out["slstm"] = [
                _quantize_stack(jax.tree.map(lambda a: a[s:e], params["slstm"]),
                                spec.w_bits) if spec.enabled else
                jax.tree.map(lambda a: a[s:e], params["slstm"])
                for (s, e, spec) in segments]
        else:
            last_spec = segments[-1][2]
            out["shared"] = (_quantize_stack(params["shared"], last_spec.w_bits)
                             if last_spec.enabled else params["shared"])
        return out
    if cfg.family == "encdec":
        enc_spec = segments[0][2]
        out["enc"] = (_quantize_stack(params["enc"], enc_spec.w_bits)
                      if enc_spec.enabled else params["enc"])
        out["dec"] = [
            _quantize_stack(jax.tree.map(lambda a: a[s:e], params["dec"]),
                            spec.w_bits) if spec.enabled else
            jax.tree.map(lambda a: a[s:e], params["dec"])
            for (s, e, spec) in segments]
        return out
    out["layers"] = [
        _quantize_stack(jax.tree.map(lambda a: a[s:e], params["layers"]),
                        spec.w_bits) if spec.enabled else
        jax.tree.map(lambda a: a[s:e], params["layers"])
        for (s, e, spec) in segments]
    return out
