"""LSQ quantizers with MSE-based (MKQ-BERT) and STE-based (LSQ/KDLSQ) scale gradients.

The paper's central algorithmic contribution (§4.1):

  Q[x] = s * round(clamp(x / s, l_min, l_max)),   l_min = -2^{k-1}+1, l_max = 2^{k-1}

Scale gradient modes
--------------------
``ste``  (LSQ / KDLSQ-BERT baseline, Esser et al. 2019):
    dQ/ds per element = round(x/s) - x/s      (in range)
                      = l_min / l_max         (clipped)
    and the incoming cotangent is applied:  ds = sum(g * dQ/ds).

``mse``  (MKQ-BERT, §4.1.2): the scale's gradient is *redefined* as the gradient of
    the quantization error itself, independent of the task cotangent:
    Gradient(s) := d(Q[x]-x)^2/ds = 2 * sum( (Q[x]-x) * round(clamp(x/s)) ).

Both modes use the standard LSQ straight-through gradient for ``x`` (pass-through
inside the clip range, zero outside).

Scales can be per-tensor (scalar) or per-channel along one axis (``per-row`` in the
paper's terminology). ``s`` must be shaped to broadcast against ``x``
(use :func:`scale_shape`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "qrange",
    "scale_shape",
    "reduce_axes_for_scale",
    "lsq_quantize",
    "fake_quant",
    "quantize_to_int",
    "dequantize",
]


def qrange(bits: int) -> tuple[int, int]:
    """Clamp bounds. Paper: l_min = -2^{k-1}+1, l_max = 2^{k-1} (k=4: [-7, 8]).

    For k=8 the paper's l_max = 128 cannot live in the int8 deployment carrier
    (it wraps to -128), so the 8-bit grid is [-127, 127]: train == deploy
    (DESIGN.md §6). k=4 keeps the paper's exact asymmetric grid.
    """
    if bits >= 8:
        return -(2 ** (bits - 1)) + 1, 2 ** (bits - 1) - 1
    return -(2 ** (bits - 1)) + 1, 2 ** (bits - 1)


def scale_shape(x_shape: tuple[int, ...], axis: Optional[int]) -> tuple[int, ...]:
    """Broadcastable shape for a scale: all-ones except ``axis`` (None => scalar ())."""
    if axis is None:
        return ()
    axis = axis % len(x_shape)
    return tuple(x_shape[i] if i == axis else 1 for i in range(len(x_shape)))


def reduce_axes_for_scale(x_ndim: int, s_shape: tuple[int, ...]) -> tuple[int, ...]:
    """Axes of x to sum over when reducing an elementwise grad to the scale's shape."""
    if s_shape == ():
        return tuple(range(x_ndim))
    # s broadcasts against x: sum over axes where s has extent 1 (plus leading axes).
    lead = x_ndim - len(s_shape)
    axes = list(range(lead))
    for i, d in enumerate(s_shape):
        if d == 1:
            axes.append(lead + i)
    return tuple(axes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quantize(x: jax.Array, s: jax.Array, bits: int, grad_mode: str) -> jax.Array:
    """Fake-quantize ``x`` with learned scale ``s`` (broadcastable against x)."""
    qmin, qmax = qrange(bits)
    z = x / s
    zq = jnp.round(jnp.clip(z, qmin, qmax))
    return (s * zq).astype(x.dtype)


def _lsq_fwd(x, s, bits, grad_mode):
    return lsq_quantize(x, s, bits, grad_mode), (x, s)


def _lsq_bwd(bits, grad_mode, res, g):
    x, s = res
    qmin, qmax = qrange(bits)
    f32 = jnp.float32
    xf, sf, gf = x.astype(f32), s.astype(f32), g.astype(f32)
    z = xf / sf
    zq = jnp.round(jnp.clip(z, qmin, qmax))
    in_range = (z >= qmin) & (z <= qmax)
    # --- gradient w.r.t. x: straight-through inside the clip range (LSQ standard).
    dx = jnp.where(in_range, gf, 0.0).astype(x.dtype)
    # --- gradient w.r.t. s.
    axes = reduce_axes_for_scale(x.ndim, s.shape)
    if grad_mode == "ste":
        elem = jnp.where(in_range, zq - z, jnp.clip(z, qmin, qmax))
        ds = jnp.sum(gf * elem, axis=axes).reshape(s.shape)
        # LSQ grad normalizer 1/sqrt(N * qmax) (Esser et al. 2019).
        n = x.size / max(s.size, 1)
        ds = ds / jnp.sqrt(n * qmax)
    elif grad_mode == "mse":
        # MKQ-BERT §4.1.2: Gradient(s) = 2 * sum((Q[x]-x) * round(clamp(x/s))).
        # The task cotangent is intentionally NOT applied; the scale descends the
        # quantization MSE directly. Averaged per-element to keep lr's in the
        # paper's reported range usable across tensor sizes.
        q = sf * zq
        n = x.size / max(s.size, 1)
        ds = 2.0 * jnp.sum((q - xf) * zq, axis=axes).reshape(s.shape) / n
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"unknown grad_mode {grad_mode!r}")
    return dx, ds.astype(s.dtype)


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def fake_quant(
    x: jax.Array,
    s: jax.Array,
    bits: int,
    grad_mode: str = "mse",
    enabled: bool = True,
) -> jax.Array:
    """QAT fake-quantization entry point (identity when disabled or bits is None)."""
    if not enabled or bits is None:
        return x
    return lsq_quantize(x, s, int(bits), grad_mode)


def quantize_to_int(x: jax.Array, s: jax.Array, bits: int) -> jax.Array:
    """Deploy-time quantization to integer codes (no gradient path).

    Uses the same qrange() grid as QAT fake-quant, so deployed int codes
    reproduce the trained grid exactly (train == deploy; see qrange for the
    k=8 int8-carrier note)."""
    qmin, qmax = qrange(bits)
    z = jnp.round(jnp.clip(x.astype(jnp.float32) / s, qmin, qmax))
    return z.astype(jnp.int8)


def dequantize(q: jax.Array, s: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * s).astype(dtype)
