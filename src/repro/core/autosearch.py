"""Sensitivity-ranked per-layer mixed-precision search (DESIGN.md §13).

"Automatic Mixed-Precision Quantization Search of BERT" (PAPERS.md) shows
per-layer bit allocation recovers the last accuracy points low-bit BERT
loses: layers differ widely in quantization sensitivity, so one global knob
(all-int4 / all-int8) either overpays bits or overpays accuracy. This module
finds the CHEAPEST per-layer assignment meeting an accuracy floor:

1. probe each layer alone at int4 (rest int8) and rank layers by the
   accuracy drop they cause — the sensitivity ranking;
2. greedily move layers to int4 from least to most sensitive, keeping a
   move only while the scored accuracy stays at or above the floor.

The scorer is a callback (``score_fn(policy) -> accuracy``) so the search is
decoupled from how candidates are evaluated — the quality bench deploys a
real artifact per candidate (benchmarks/table1_glue.py --artifact), unit
tests use synthetic scorers. Cost: ``num_layers + 1`` probe scores plus at
most ``num_layers`` greedy scores — and with
:func:`cached_probe_scorer` wrapped around the deploy path, each of those
scores costs an EVAL, not a re-deploy: every candidate's packed params are
assembled bit-exactly by slicing two cached uniform-grid deploys.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .policy import QuantPolicy

__all__ = ["SearchResult", "cached_probe_scorer", "load_search_policy",
           "search_mixed_precision"]


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Outcome of one search run.

    policy       the cheapest policy found that meets the floor
    accuracy     its scored accuracy (the all-int8 base accuracy when no
                 int4 move survived)
    base_accuracy  the all-int8 starting accuracy
    sensitivity  ((layer, accuracy_drop), ...) ranked least-sensitive first
    trajectory   ((candidate_int4_layers, accuracy, accepted), ...) — every
                 greedy step, for the bench report
    """

    policy: QuantPolicy
    accuracy: float
    base_accuracy: float
    sensitivity: tuple
    trajectory: tuple
    floor: float = float("-inf")     # the resolved accuracy floor used

    def describe(self) -> str:
        i4 = sorted(self.policy.int4_layers or ())
        return (f"int4_layers={i4} acc={self.accuracy:.4f} "
                f"(base int8 {self.base_accuracy:.4f}, "
                f"floor {self.floor:.4f}, "
                f"{len(self.trajectory)} greedy steps)")


def search_mixed_precision(num_layers: int,
                           score_fn: Callable[[QuantPolicy], float], *,
                           accuracy_floor: float | None = None,
                           floor_delta: float | None = None,
                           fp_score: float | None = None,
                           mode: str = "int",
                           default_bits: int = 8,
                           grad_mode: str = "mse",
                           layers: Sequence[int] | None = None
                           ) -> SearchResult:
    """Greedy sensitivity-ordered descent from all-int8 toward all-int4.

    The floor is given EITHER absolutely (``accuracy_floor``) or relatively
    (``floor_delta``: allowed drop below a reference score — ``fp_score``
    when supplied, else the all-int8 base this search measures anyway).
    Relative floors are how the quality bench states its gate ("within 5
    points of fp32") without hard-coding a dataset-specific number; exactly
    one of the two must be set.

    ``layers`` restricts the candidate set (default: every layer). A layer
    whose greedy move drops accuracy below the floor is skipped, not
    terminal: a later (more sensitive alone, cheaper combined) layer may
    still fit under the floor.
    """
    if (accuracy_floor is None) == (floor_delta is None):
        raise ValueError("pass exactly one of accuracy_floor / floor_delta")
    if accuracy_floor is not None and fp_score is not None:
        raise ValueError("fp_score only applies to a floor_delta floor")
    cand = list(range(num_layers)) if layers is None else list(layers)

    def mk(int4: Sequence[int]) -> QuantPolicy:
        return QuantPolicy(num_layers=num_layers, mode=mode,
                           int4_layers=tuple(sorted(int4)),
                           default_bits=default_bits, grad_mode=grad_mode)

    base = float(score_fn(mk(())))
    floor = (accuracy_floor if accuracy_floor is not None
             else (fp_score if fp_score is not None else base) - floor_delta)
    probes = [(l, base - float(score_fn(mk((l,))))) for l in cand]
    ranking = tuple(sorted(probes, key=lambda t: (t[1], t[0])))

    chosen: list[int] = []
    best = base
    trajectory = []
    for l, _drop in ranking:
        trial = chosen + [l]
        acc = float(score_fn(mk(trial)))
        ok = acc >= floor
        trajectory.append((tuple(sorted(trial)), acc, ok))
        if ok:
            chosen, best = trial, acc
    return SearchResult(policy=mk(chosen), accuracy=best,
                        base_accuracy=base, sensitivity=ranking,
                        trajectory=tuple(trajectory), floor=floor)


def cached_probe_scorer(deploy_fn: Callable[[QuantPolicy], object],
                        score_fn: Callable[[object], float]
                        ) -> Callable[[QuantPolicy], float]:
    """A drop-in ``score_fn`` for :func:`search_mixed_precision` that makes
    each probe cost a SCORE, not a deploy (DESIGN.md §13).

    The naive probe loop re-deploys the full model per candidate —
    ``num_layers + 1`` deploys (weight-scale calibration, activation
    calibration forwards, packing) before the greedy walk even starts. But
    a deployed candidate is assembled from ingredients that never depend on
    the MIX of layers: ``deploy()``'s calibration forward runs in fp (so a
    learned scale depends only on its OWN layer's grid), and packed codes /
    scales are per-layer. A mixed-policy deploy is therefore EXACTLY the
    per-layer interleave of the all-int4 and all-int8 grid deploys —
    bit-for-bit, not approximately (asserted against the full probe by
    benchmarks/table1_glue.py).

    So this scorer runs ``deploy_fn`` once per uniform grid (lazily), then
    assembles every candidate by slicing the stacked layer segments out of
    the cached grids under the candidate's own plan; only ``score_fn``
    (the cached eval split) runs per candidate. Scores memoize on the
    per-layer bit vector, so repeated candidates are free. Families whose
    deployed tree has no ``'layers'`` stack (xlstm / hybrid / encdec), or
    a bit width outside {4, default_bits}, fall back to a full
    ``deploy_fn`` call for that candidate.
    """
    grids: dict = {}    # w_bits -> uniform-grid DeployedModel
    memo: dict = {}     # per-layer bit vector -> score

    def grid_for(policy: QuantPolicy, bits: int):
        if bits not in grids:
            all_l = tuple(range(policy.num_layers))
            grids[bits] = deploy_fn(dataclasses.replace(
                policy, int4_layers=(all_l if bits == 4 else ()),
                last_k_int4=0))
        return grids[bits]

    def assemble(policy: QuantPolicy):
        import jax

        from ..deploy import DeployedModel, ExecutionPlan

        base = grid_for(policy, policy.default_bits)
        if "layers" not in base.params:
            return deploy_fn(policy)        # per-family stacks: full path
        plan = ExecutionPlan.build(base.plan.cfg, policy,
                                   **base.plan.build_kwargs())
        stacks = []
        for (s, e, spec) in plan.segments:
            if spec.w_bits not in (4, policy.default_bits):
                return deploy_fn(policy)
            g = grid_for(policy, spec.w_bits)
            for (gs, ge, _), stack in zip(g.plan.segments,
                                          g.params["layers"]):
                if gs <= s and e <= ge:
                    stacks.append(jax.tree.map(
                        lambda a, lo=s, hi=e, off=gs: a[lo - off:hi - off],
                        stack))
                    break
            else:                            # grid segmented unexpectedly
                return deploy_fn(policy)
        params = dict(base.params)
        params["layers"] = stacks
        return DeployedModel(plan=plan, params=params)

    def score(policy: QuantPolicy) -> float:
        key = tuple(policy.weight_bits_vector().tolist())
        if key not in memo:
            memo[key] = float(score_fn(assemble(policy)))
        return memo[key]

    return score


def load_search_policy(path: str, num_layers: int) -> QuantPolicy:
    """Reconstruct a deployable ``QuantPolicy`` from a search artifact JSON.

    Accepts either form the toolchain writes:

    * a quality-bench payload (``benchmarks/table1_glue.py --search``) —
      the search result lives under a ``"search"`` key whose
      ``chosen_int4_layers`` is the winning assignment;
    * a bare ``dataclasses.asdict(QuantPolicy)`` dump (the DeployedModel
      artifact meta shape), loaded via ``QuantPolicy.from_dict``.

    ``num_layers`` pins the policy to the model actually being served —
    the bench may have searched a reduced config, and a chosen layer index
    outside ``[0, num_layers)`` is a config mismatch, not a policy."""
    import json

    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload, dict):
        raise ValueError(f"{path}: expected a JSON object, "
                         f"got {type(payload).__name__}")
    search = payload.get("search", payload)
    if "chosen_int4_layers" in search:
        chosen = tuple(sorted(int(l) for l in search["chosen_int4_layers"]))
        bad = [l for l in chosen if not 0 <= l < num_layers]
        if bad:
            raise ValueError(
                f"{path}: chosen_int4_layers {bad} outside the served "
                f"model's [0, {num_layers}) layer range")
        return QuantPolicy(num_layers=num_layers, mode="int",
                           int4_layers=chosen)
    pol = QuantPolicy.from_dict(dict(search))
    if pol.num_layers != num_layers:
        raise ValueError(
            f"{path}: policy num_layers={pol.num_layers} does not match "
            f"the served model's {num_layers}")
    return pol
