"""MINI (MiniLM-style) distillation with scale factors (paper §4.2).

Last-layer-only self-attention relation distillation:

  L_attention = sum_a KL( A_a^S || A_a^T ),      A = softmax(q k^T / sqrt(d_r))
  L_value     = sum_a KL( VR_a^S || VR_a^T ),    VR = softmax(v v^T / sqrt(d_r))
  L_final     = L_train + alpha * L_output + beta * (L_attention + L_value)

Because only the LAST layer's q/k/v taps are used, the teacher may be deeper
than the student with no layer mapping. Teacher width/head-count mismatch is
handled MiniLM-v2 style: q/k/v are re-split into ``num_relation_heads`` before
building relations, so only the relation-head count must agree.

For attention-free blocks (xLSTM, Mamba2 — DESIGN.md §5) the relation terms are
inapplicable; :func:`hidden_state_loss` is the documented substitute.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "kl_from_logits",
    "relation_distribution",
    "minilm_losses",
    "output_loss",
    "hidden_state_loss",
    "combine_losses",
]

_NEG_INF = -1e9


def kl_from_logits(p_logits: jax.Array, q_logits: jax.Array,
                   mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean KL(P || Q) over leading dims; distributions over the last axis."""
    p_log = jax.nn.log_softmax(p_logits.astype(jnp.float32), axis=-1)
    q_log = jax.nn.log_softmax(q_logits.astype(jnp.float32), axis=-1)
    kl = jnp.sum(jnp.exp(p_log) * (p_log - q_log), axis=-1)
    if mask is not None:
        kl = kl * mask
        return jnp.sum(kl) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(kl)


def relation_distribution(a: jax.Array, b: jax.Array, num_relation_heads: int,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Relation logits softmax'd over keys: (B, S, D) x (B, S, D) -> (B, R, S, S).

    Splits the feature dim into R relation heads (MiniLM-v2) so student/teacher
    widths may differ as long as D is divisible by R on each side.
    """
    B, S, D = a.shape
    R = num_relation_heads
    if D % R:
        raise ValueError(f"feature dim {D} not divisible by relation heads {R}")
    dr = D // R
    ah = a.reshape(B, S, R, dr).transpose(0, 2, 1, 3)
    bh = b.reshape(B, S, R, dr).transpose(0, 2, 1, 3)
    logits = jnp.einsum("brsd,brtd->brst", ah, bh) / jnp.sqrt(jnp.float32(dr))
    if mask is not None:  # mask keys: (B, S) -> (B, 1, 1, S)
        logits = jnp.where(mask[:, None, None, :] > 0, logits, _NEG_INF)
    return logits


def minilm_losses(taps_s: dict, taps_t: dict, num_relation_heads: int,
                  mask: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """(L_attention, L_value) from last-layer q/k/v taps {'q','k','v': (B,S,D)}."""
    qmask = None if mask is None else mask
    attn_s = relation_distribution(taps_s["q"], taps_s["k"], num_relation_heads, mask)
    attn_t = relation_distribution(taps_t["q"], taps_t["k"], num_relation_heads, mask)
    l_attn = kl_from_logits(attn_s, attn_t,
                            None if qmask is None else qmask[:, None, :])
    val_s = relation_distribution(taps_s["v"], taps_s["v"], num_relation_heads, mask)
    val_t = relation_distribution(taps_t["v"], taps_t["v"], num_relation_heads, mask)
    l_val = kl_from_logits(val_s, val_t,
                           None if qmask is None else qmask[:, None, :])
    return l_attn, l_val


def output_loss(logits_s: jax.Array, logits_t: jax.Array,
                kind: str = "mse", mask: Optional[jax.Array] = None) -> jax.Array:
    """L_output: MSE or KL on the network outputs (paper §3.3)."""
    if kind == "mse":
        d = jnp.square(logits_s.astype(jnp.float32) - logits_t.astype(jnp.float32))
        d = jnp.mean(d, axis=-1)
        if mask is not None:
            return jnp.sum(d * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(d)
    if kind == "kl":
        return kl_from_logits(logits_t, logits_s, mask)  # teacher as target dist
    raise ValueError(f"unknown output loss {kind!r}")


def hidden_state_loss(h_s: jax.Array, h_t: jax.Array,
                      mask: Optional[jax.Array] = None) -> jax.Array:
    """Substitute relation loss for attention-free blocks (DESIGN.md §5)."""
    d = jnp.mean(jnp.square(h_s.astype(jnp.float32) - h_t.astype(jnp.float32)), -1)
    if mask is not None:
        return jnp.sum(d * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(d)


def combine_losses(l_train: jax.Array, l_output: jax.Array, l_attn: jax.Array,
                   l_value: jax.Array, alpha: float = 10.0, beta: float = 1.0):
    """Paper eq. (10). Returns (L_final, dict of parts)."""
    total = l_train + alpha * l_output + beta * (l_attn + l_value)
    return total, {
        "loss/train": l_train, "loss/output": l_output,
        "loss/attention": l_attn, "loss/value": l_value, "loss/total": total,
    }
