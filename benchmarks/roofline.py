"""Roofline aggregation (deliverable g): reads experiments/dryrun/*.json and
emits, per (arch x shape x mesh):

  compute_s / memory_s / collective_s  (per-device, from the compiled HLO),
  the dominant term, MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (decode),
  and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat/dispatch waste).

An ANALYTIC bytes column cross-checks the parser's memory term for decode
cells (weights + KV-cache reads — the CPU backend's copy-insertion inflates
the parsed value; see EXPERIMENTS.md methodology).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM = 819e9
ICI = 50e9


def param_count(cfg, active_only=False):
    """Non-embedding parameter count from the config (analytic)."""
    d, L = cfg.d_model, cfg.num_layers
    H, Hkv, hd, f = cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_ff
    attn = d * H * hd + 2 * d * Hkv * hd + H * hd * d
    if cfg.family == "moe":
        fe = cfg.expert_d_ff
        e_used = cfg.top_k if active_only else cfg.num_experts
        ffn = 3 * d * fe * e_used
        if cfg.shared_expert_d_ff:
            ffn += 3 * d * cfg.shared_expert_d_ff
        return L * (attn + ffn)
    if cfg.family == "xlstm":
        di = cfg.ssm_expand * d
        G = L // cfg.slstm_every
        n_m = L - G
        m = 2 * d * di + 3 * di * di + di * d
        s = 4 * d * d + d * d
        return n_m * m + G * s
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * d
        Hs = di // cfg.ssm_head_dim
        N = cfg.ssm_state
        mamba = 2 * d * di + d * (2 * N + Hs) + di * d
        G = L // cfg.attn_every
        shared = attn + 3 * d * f
        # shared block: ONE weight set, applied G times (compute counts Gx)
        return L * mamba + shared * (G if active_only else 1)
    if cfg.family == "encdec":
        ffn = 2 * d * f if cfg.act == "gelu" else 3 * d * f
        return cfg.enc_layers * (attn + ffn) + cfg.dec_layers * (
            2 * attn + ffn)
    ffn = 3 * d * f if cfg.act == "swiglu" else 2 * d * f
    return L * (attn + ffn)


def model_flops(cfg, shape, chips):
    """Per-device useful model FLOPs for the cell."""
    D = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        n = param_count(cfg, active_only=True)
        return 6 * n * D / chips
    if shape.kind == "prefill":
        n = param_count(cfg, active_only=True)
        return 2 * n * D / chips
    # decode: one token per sequence; active params only
    n = param_count(cfg, active_only=True)
    return 2 * n * shape.global_batch / chips


def analytic_decode_bytes(cfg, shape, chips, policy="mkq50"):
    """weights (mixed int4/int8) + KV reads per decode step, per device."""
    n = param_count(cfg)
    wbytes = n * 0.75  # 50% int4 (0.5 B) + 50% int8 (1 B)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family in ("xlstm", "hybrid"):
        kv = 0
        if cfg.family == "hybrid":
            G = cfg.num_layers // cfg.attn_every
            kv = G * B * S * cfg.num_kv_heads * cfg.hd * 2 * 2
        di = cfg.ssm_expand * cfg.d_model
        state = cfg.num_layers * B * (di // cfg.ssm_head_dim) * \
            cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
        return (wbytes + kv + state) / chips
    L = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    kv = L * B * S * cfg.num_kv_heads * cfg.hd * 2 * 2
    return (wbytes + kv) / chips


def load_cells(out_dir="experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main(out_dir="experiments/dryrun"):
    cells = load_cells(out_dir)
    print("roofline,arch,shape,mesh,status,compute_ms,memory_ms,"
          "collective_ms,dominant,model_tflops,useful_ratio,"
          "analytic_mem_ms,fits_16g")
    for c in cells:
        if c.get("tag"):
            continue
        if c["status"] != "ok":
            print(f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
                  f"{c['status']},,,,,,,,")
            continue
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        chips = c["chips"]
        t = c["roofline_terms_s"]
        mf = model_flops(cfg, shape, chips)
        ratio = mf / max(c["hlo_analysis"]["flops"], 1)
        amem = ""
        if shape.kind == "decode":
            amem = f"{analytic_decode_bytes(cfg, shape, chips) / HBM * 1e3:.3f}"
        print(f"roofline,{c['arch']},{c['shape']},{c['mesh']},ok,"
              f"{t['compute_s'] * 1e3:.2f},{t['memory_s'] * 1e3:.2f},"
              f"{t['collective_s'] * 1e3:.2f},{c['dominant']},"
              f"{mf / 1e12:.3f},{ratio:.3f},{amem},"
              f"{c['memory']['fits_16g']}")


if __name__ == "__main__":
    main()
