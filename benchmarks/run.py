"""Benchmark runner: one section per paper table + the roofline aggregation.

``python -m benchmarks.run``           — full pass (tables 1-3 + roofline)
``python -m benchmarks.run --quick``   — reduced grids (CI)
Prints ``name,us_per_call,derived`` CSV sections.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default="",
                   help="comma list: table1,table2,table3,roofline")
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (roofline, serve_latency, table1_glue, table2_speedup,
                   table3_ablation)
    sections = [("table1", lambda: table1_glue.main(quick=args.quick)),
                ("table2", lambda: table2_speedup.main(quick=args.quick)),
                ("table3", lambda: table3_ablation.main(quick=args.quick)),
                ("serve", lambda: serve_latency.main(quick=args.quick)),
                ("roofline", roofline.main)]
    failures = 0
    for name, fn in sections:
        if only and name not in only:
            continue
        print(f"# ==== {name} ====", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
