"""Serving latency/throughput benchmark: weight precision x KV-cache precision
(paper Table 2's deployment claim, measured end-to-end through the serving
subsystem).

For each variant the same tiny gelu-FFN causal LM is deployed and a burst of
requests runs through ``repro.serving.ServingEngine`` (chunked prefill +
batched decode). The ``kv_bits`` axis (DESIGN.md §8) covers the fp cache and
the int8/int4 packed cache with the fused Pallas decode-attention kernel on
the deployed-int variants. Reports tokens/sec, p50/p99 engine-step latency
and per-request time-to-first-token / queue-wait percentiles (DESIGN.md §10)
from the engine's ServeMetrics recorder, and writes a machine-readable
``BENCH_serve.json`` consumed by the CI bench gate (``tools/check_bench.py``
— the gate keys on ``tokens_per_s`` only and tolerates the extra keys).

A second, NON-gated section (``prefix_scenario``, DESIGN.md §11) measures
the repeated-prefix workload: every request shares a common system-prompt
prefix, served once with the prefix cache off and once on. Reported per
variant: prefill tokens actually computed, prefix hit rate, and TTFT p50 —
the reuse claim is "≥ 50% fewer prefill tokens computed on a warm cache",
which is deterministic, unlike interpret-mode wall clocks.

Runs on CPU: the int paths execute the Pallas kernels in interpret mode (the
same code path that compiles to Mosaic on TPU), with the int4 variant using
the fused dequant+bias+GELU decode epilogue. Interpret-mode timings measure
dispatch overhead, not MXU throughput — the point here is that the harness
measures the real serving path; on TPU the same script reports the paper's
speedup trajectory.

``python -m benchmarks.serve_latency [--quick] [--out BENCH_serve.json]``
"""
from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.serving import GenerationRequest, ServingEngine


def _build(cfg, policy, backend, fuse, act_bits=None):
    """Deployed params for (policy, backend, fuse, act_bits).

    The packed weights are independent of kv_bits, so callers cache these
    across the kv sweep and only the (cheap) per-variant plan is rebuilt.
    ``act_bits`` changes the stored activation-scale grid (DESIGN.md §13),
    so it is part of the cache key."""
    plan = ExecutionPlan.build(cfg, policy, backend=backend,
                               fuse_epilogue=fuse, act_bits=act_bits)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    if policy is not None:
        params = deploy(params, plan).params
    return params


def _serve_burst(eng, cfg, n_requests, max_new, seed=0, prefix=None):
    rng = np.random.default_rng(seed)
    shared = (np.zeros(0, np.int32) if prefix is None
              else np.asarray(prefix, np.int32))
    for _ in range(n_requests):
        plen = int(rng.integers(4, 12))
        tail = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(GenerationRequest(prompt=np.concatenate([shared, tail]),
                                     max_new_tokens=max_new))
    eng.run_until_drained()
    eng.pop_done()


def _warmup(eng, cfg):
    """Compile every code path the timed burst will hit OUTSIDE the metrics
    window: the measured prompt lengths [4, 12) map to prefill buckets
    {8, 16}, so one request per bucket plus a decode step. Otherwise a
    one-off XLA compile lands inside the timed window and dominates tok/s."""
    rng = np.random.default_rng(123)
    for plen in (6, 11):                     # buckets 8 and 16
        eng.submit(GenerationRequest(
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=2))
    eng.run_until_drained()
    eng.pop_done()


def run_variants(quick: bool = False) -> dict:
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    n_requests = 3 if quick else 8
    max_new = 4 if quick else 8
    slots = 2

    int8_pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=0)
    int4_pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n)
    # (name, policy, backend, fuse_epilogue, kv_bits, act_bits) — act_bits
    # (DESIGN.md §13): None follows the policy (W4A4 on int4 layers), 8
    # retargets activations to the int8 grid, 0 is the fp-activation
    # weight-only parity path (reference backend). The a8/afp rows chart
    # the W4A4 speedup trajectory; informational, never gated.
    variants = [
        ("fp32_kv16", None, "reference", False, 16, None),
        ("int8_kv16", int8_pol, "pallas", False, 16, None),
        ("int4_kv16", int4_pol, "pallas", True, 16, None),
        ("int4_kv8", int4_pol, "pallas", True, 8, None),
        ("int4_kv4", int4_pol, "pallas", True, 4, None),
        ("int4_kv4_a8", int4_pol, "pallas", True, 4, 8),
        ("int4_kv16_afp", int4_pol, "reference", False, 16, 0),
    ]
    results = {}
    built = {}   # identical deployed params reused across kv_bits variants
    for name, policy, backend, fuse, kv_bits, act_bits in variants:
        key = (id(policy), backend, fuse, act_bits)
        if key not in built:
            built[key] = _build(cfg, policy, backend, fuse, act_bits)
        params = built[key]
        plan = ExecutionPlan.build(cfg, policy, backend=backend,
                                   kv_bits=kv_bits, fuse_epilogue=fuse,
                                   act_bits=act_bits)
        eng = ServingEngine(params, plan, slots=slots, max_len=64)
        _warmup(eng, cfg)
        # best-of-3 bursts: host-scheduler noise on shared runners is
        # one-sided (contention only ever slows a run down), so the max
        # tok/s burst is the least-contended measurement of the same code
        # path — single tiny bursts flapped the CI gate by 2x run-to-run
        eng.metrics.pop_summary()           # drop warmup events
        best = None
        for rep in range(3):
            _serve_burst(eng, cfg, n_requests=n_requests, max_new=max_new,
                         seed=rep)
            s = eng.metrics.pop_summary()   # drain: bounded between bursts
            if best is None or s["tokens_per_s"] > best["tokens_per_s"]:
                best = s
        results[name] = best
    return results


def run_prefix_scenario(quick: bool = False) -> dict:
    """Repeated-prefix workload (DESIGN.md §11): every request = one shared
    16-token system prefix + a random tail. Served with the prefix cache off
    vs on (batched prefill on in both), same prompts. The reuse headline is
    ``prefill_tokens`` — tokens actually computed — which is deterministic;
    tok/s and TTFT ride along for trend-watching but are NOT gated."""
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    n_requests = 4 if quick else 12
    max_new = 4 if quick else 8
    rng = np.random.default_rng(7)
    prefix = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)

    int4_pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=n)
    int8_pol = QuantPolicy(num_layers=n, mode="int", last_k_int4=0)
    variants = [("int4_kv4", int4_pol, 4)]
    if not quick:
        variants.append(("int8_kv8", int8_pol, 8))
    out = {}
    for name, policy, kv_bits in variants:
        params = _build(cfg, policy, "pallas", kv_bits == 4)
        for mode, budget in (("off", 0), ("on", 32 << 20)):
            plan = ExecutionPlan.build(cfg, policy, backend="pallas",
                                       kv_bits=kv_bits,
                                       fuse_epilogue=kv_bits == 4,
                                       prefix_cache=budget, prefill_batch=4)
            eng = ServingEngine(params, plan, slots=2, max_len=64)
            _warmup(eng, cfg)
            eng.metrics.pop_summary()
            best = None
            for rep in range(3):
                _serve_burst(eng, cfg, n_requests=n_requests,
                             max_new=max_new, seed=rep, prefix=prefix)
                s = eng.metrics.pop_summary()
                if best is None or s["tokens_per_s"] > best["tokens_per_s"]:
                    best = s
            # all prefill/prefix counters come from the LAST rep as one
            # coherent set: rep 0 warms the cache (its first request
            # computes the prefix), reps 1-2 are fully warm and their
            # counts are deterministic — unlike the timings, which keep the
            # best-of-3 selection above. Mixing reps per-key would emit an
            # internally inconsistent record.
            for key in ("prefill_tokens", "prefill_steps", "prefix_lookups",
                        "prefix_hit_rate", "prefill_tokens_saved",
                        "prefix_reuse_frac"):
                if key in s:
                    best[key] = s[key]
                else:
                    best.pop(key, None)
            out[f"{name}_prefix_{mode}"] = best
    return out


def main(quick: bool = False, out: str | None = "BENCH_serve.json") -> None:
    results = run_variants(quick=quick)
    print("variant,tokens_per_s,decode_p50_ms,decode_p99_ms,"
          "prefill_p50_ms,prefill_p99_ms,ttft_p50_ms,queue_wait_p50_ms,"
          "total_tokens")
    for name, s in results.items():
        print(f"{name},{s['tokens_per_s']:.1f},"
              f"{s.get('decode_p50_ms', 0):.2f},"
              f"{s.get('decode_p99_ms', 0):.2f},"
              f"{s.get('prefill_p50_ms', 0):.2f},"
              f"{s.get('prefill_p99_ms', 0):.2f},"
              f"{s.get('ttft_p50_ms', 0):.2f},"
              f"{s.get('queue_wait_p50_ms', 0):.2f},"
              f"{s['total_tokens']}")
    prefix = run_prefix_scenario(quick=quick)
    print("prefix_variant,prefill_tokens,prefix_hit_rate,"
          "prefill_tokens_saved,ttft_p50_ms,tokens_per_s")
    for name, s in prefix.items():
        print(f"{name},{s['prefill_tokens']},"
              f"{s.get('prefix_hit_rate', 0):.2f},"
              f"{s.get('prefill_tokens_saved', 0)},"
              f"{s.get('ttft_p50_ms', 0):.2f},"
              f"{s['tokens_per_s']:.1f}")
    if out:
        payload = {
            "bench": "serve_latency",
            "quick": quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "variants": results,
            # informational, never gated (tools/check_bench.py prints it):
            # repeated-prefix workload, cache off vs on (DESIGN.md §11)
            "prefix_scenario": prefix,
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[serve_latency] wrote {out}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--out", default="BENCH_serve.json",
                   help="machine-readable results path ('' to skip)")
    a = p.parse_args()
    main(quick=a.quick, out=a.out or None)
