"""Serving latency/throughput benchmark: int4 vs int8 vs fp32 (paper Table 2's
deployment claim, measured end-to-end through the serving subsystem).

For each precision the same tiny gelu-FFN causal LM is deployed and a burst
of requests runs through ``repro.serving.ServingEngine`` (chunked prefill +
batched decode). Reports tokens/sec and p50/p99 engine-step latency from the
engine's ServeMetrics recorder.

Runs on CPU: the int paths execute the Pallas kernels in interpret mode (the
same code path that compiles to Mosaic on TPU), with the int4 variant using
the fused dequant+bias+GELU decode epilogue. Interpret-mode timings measure
dispatch overhead, not MXU throughput — the point here is that the harness
measures the real serving path; on TPU the same script reports the paper's
speedup trajectory.

``python -m benchmarks.serve_latency [--quick]``
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.core.qat import (calibrate_weight_scales, default_bits_fn,
                            deploy_params)
from repro.models import api
from repro.serving import Request, ServeMetrics, ServingEngine


def _build(cfg, policy, use_pallas, fuse):
    segments = api.segments_for(cfg, policy, use_pallas=use_pallas,
                                fuse_epilogue=fuse)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    if policy is not None:
        params = calibrate_weight_scales(params,
                                         default_bits_fn(cfg, policy))
        params = deploy_params(params, cfg, segments)
    return params, segments


def _serve_burst(eng, cfg, n_requests, max_new, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(prompt=rng.integers(1, cfg.vocab_size, plen)
                           .astype(np.int32), max_new_tokens=max_new))
    eng.run_until_drained()


def main(quick: bool = False) -> None:
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n = cfg.num_layers
    n_requests = 3 if quick else 8
    max_new = 4 if quick else 8
    slots = 2

    variants = [
        ("fp32", None, False, False),
        ("int8", QuantPolicy(num_layers=n, mode="int", last_k_int4=0),
         True, False),
        ("int4", QuantPolicy(num_layers=n, mode="int", last_k_int4=n),
         True, True),  # all-int4 + fused decode epilogue
    ]
    print("variant,tokens_per_s,decode_p50_ms,decode_p99_ms,"
          "prefill_p50_ms,prefill_p99_ms,total_tokens")
    for name, policy, use_pallas, fuse in variants:
        params, segments = _build(cfg, policy, use_pallas, fuse)
        eng = ServingEngine(params, cfg, segments, slots=slots, max_len=64)
        # warmup: compile prefill buckets + decode step outside the metrics
        _serve_burst(eng, cfg, n_requests=2, max_new=2, seed=123)
        eng.metrics = ServeMetrics()
        _serve_burst(eng, cfg, n_requests=n_requests, max_new=max_new)
        s = eng.metrics.summary()
        print(f"{name},{s['tokens_per_s']:.1f},"
              f"{s.get('decode_p50_ms', 0):.2f},"
              f"{s.get('decode_p99_ms', 0):.2f},"
              f"{s.get('prefill_p50_ms', 0):.2f},"
              f"{s.get('prefill_p99_ms', 0):.2f},"
              f"{s['total_tokens']}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    main(quick=p.parse_args().quick)
