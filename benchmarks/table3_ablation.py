"""Table 3 reproduction: ablations on TinyBERT4 with last-2-layers int4.

Rows (paper §5.5): full MKQ / w-o MINI KD / w-o output KD / w-o LSQ
(quantization scales frozen after calibration). Expectation validated:
full MKQ is the best option; each removed component costs accuracy.
"""
from __future__ import annotations

import time

import jax

from repro.core.policy import QuantPolicy
from repro.models import api
from repro.models.bert import init_bert_classifier

from . import common


def run(steps=150, seed=0, quick=False):
    if quick:
        steps = 80
    cfg = common.student_config()
    tcfg = common.teacher_config()
    from repro.data.synthetic import SyntheticClassification
    data = SyntheticClassification(cfg.vocab_size, 24, 64,
                                   num_classes=common.NUM_CLASSES, seed=seed)
    key = jax.random.PRNGKey(seed)
    tsegs = api.segments_for(tcfg, None)
    teacher = common.train_best(
        lambda: init_bert_classifier(tcfg, common.NUM_CLASSES, key),
        tcfg, tsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))
    fsegs = api.segments_for(cfg, None)
    fp_student = common.train_best(
        lambda: init_bert_classifier(cfg, common.NUM_CLASSES,
                                     jax.random.fold_in(key, 1)),
        cfg, fsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))

    pol = QuantPolicy(num_layers=cfg.num_layers, mode="fake", last_k_int4=2,
                      grad_mode="mse")
    segs = api.segments_for(cfg, pol)
    variants = {
        "full_mkq": dict(use_mini_kd=True, use_output_kd=True,
                         freeze_scales=False),
        "wo_mini_kd": dict(use_mini_kd=False, use_output_kd=True,
                           freeze_scales=False),
        "wo_output_kd": dict(use_mini_kd=True, use_output_kd=False,
                             freeze_scales=False),
        "wo_lsq": dict(use_mini_kd=True, use_output_kd=True,
                       freeze_scales=True),
    }
    results = []
    calibrated = common.build_qat_student(cfg, pol, data, fp_student)
    for name, kw in variants.items():
        params = common.train_best(
            lambda: calibrated, cfg, segs, data, steps=steps,
            lrs=(1e-3, 5e-4), teacher=teacher, teacher_cfg=tcfg,
            teacher_segments=tsegs, **kw)
        results.append((name, common.evaluate(params, cfg, segs, data)))
    return results


def main(quick=False):
    t0 = time.perf_counter()
    results = run(quick=quick)
    print("table3,name,us_per_call,derived")
    for name, acc in results:
        print(f"table3,{name},-,accuracy={acc:.4f}")
    best = max(results, key=lambda r: r[1])[0]
    print(f"table3,best_variant,-,{best}")
    print(f"table3,total,us_per_call,{(time.perf_counter()-t0)*1e6:.0f}")
    return results


if __name__ == "__main__":
    main()
