"""Trace-driven serving load benchmark: SLO goodput under offered load
(DESIGN.md §12).

Replaces the fixed-burst tok/s measurement as the gated serving bench. Two
sections, one JSON (``BENCH_load.json``):

* **wall** — the closed-loop generator (``repro.serving.loadgen``) replays a
  Poisson arrival mix (mixed prompt/output lengths, shared-prefix traffic
  through the PR-5 prefix cache, priorities, deadline traffic, mid-flight
  cancellations) against real engines in wall-clock mode, repeated over
  trials, and reports goodput + latency percentiles with bootstrap
  confidence intervals. SLO thresholds and the offered rate are
  **self-calibrated** from a warmup burst on the same host (multiples of the
  measured prefill/decode step cost), the same normalization trick the old
  tok/s gate used: host speed cancels, so a baseline recorded on a dev box
  gates runs on slower CI runners. ``tools/check_bench.py`` gates on
  goodput **interval overlap** — see DESIGN.md §12.
* **virtual** — the same generator in virtual-clock mode (deterministic
  ``VirtualClock`` + fixed ``VirtualCost``): steady, overload-shedding and
  cancel-churn scenarios whose goodput/shed/reject numbers are exact and
  machine-independent (arrival seeds, costs and scheduling are all
  deterministic; token values never influence timing). Two back-to-back
  runs must produce an identical section — CI asserts exactly that.

``python -m benchmarks.serve_load [--quick] [--trials N] [--trace T.json]
                                  [--out BENCH_load.json]``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import ExecutionPlan, deploy
from repro.models import api
from repro.models.bert import init_bert_classifier, tinybert_config
from repro.serving import (SLO, GenerationRequest, MultiTenantEngine,
                           ReplicaSet, ServingEngine, VirtualClock,
                           VirtualCost, Workload, bootstrap_summary,
                           make_arrivals, run_load, run_trials)
from repro.kernels.kv_pack import kv_row_bytes
from repro.serving.loadgen import load_trace
from repro.serving.prefix_cache import PREFIX_BLOCK

#: SLO / load calibration multipliers over the measured warmup step cost.
#: Generous on purpose: a healthy run clears them with ~10x headroom, so the
#: gate only trips on systematic degradation, not scheduler jitter.
TTFT_MULT = 10.0       # ttft_s  = TTFT_MULT * (prefill_p50 + decode_p50)
ITL_MULT = 8.0         # itl_s   = ITL_MULT  * (prefill_p50 + decode_p50)
DEADLINE_MULT = 30.0   # deadline_s = DEADLINE_MULT * service_s
UTILIZATION = 0.5      # offered rate as a fraction of measured capacity


def _build_engine(policy, backend, fuse, kv_bits, *, prefix_cache=0,
                  slots=2, max_len=64, clock=None, max_queue=None,
                  warmup=False):
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    plan = ExecutionPlan.build(cfg, policy, backend=backend, kv_bits=kv_bits,
                               fuse_epilogue=fuse, prefix_cache=prefix_cache)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    if policy is not None:
        params = deploy(params, plan).params
    kwargs = {} if clock is None else {"clock": clock}
    eng = ServingEngine(params, plan, slots=slots, max_len=max_len,
                        max_queue=max_queue, warmup=warmup, **kwargs)
    return eng, cfg


def _warmup_and_calibrate(eng, cfg, w: Workload) -> dict:
    """Compile every code path the load mix will hit OUTSIDE the measured
    window and derive host-normalized SLOs + offered rate from the measured
    step costs (prefill/decode p50)."""
    rng = np.random.default_rng(123)
    for plen in (6, 11):                       # buckets 8 and 16
        eng.submit(GenerationRequest(
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=2))
    if eng.prefix_cache is not None and w.shared_prefix_frac > 0:
        # shared-prefix bucket (prefix + tail -> bucket 32): cold publish,
        # then a warm hit, so both chunked-prefill paths are compiled
        prefix = rng.integers(1, cfg.vocab_size,
                              w.shared_prefix_len).astype(np.int32)
        for _ in range(2):
            tail = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
            eng.submit(GenerationRequest(
                prompt=np.concatenate([prefix, tail]), max_new_tokens=2))
    eng.run_until_drained()
    eng.pop_done()
    s = eng.metrics.pop_summary()              # drop warmup events
    prefill_s = s.get("prefill_p50_ms", 50.0) / 1e3
    decode_s = s.get("decode_p50_ms", 10.0) / 1e3
    step_s = prefill_s + decode_s
    mean_new = (w.new_tokens[0] + w.new_tokens[1]) / 2.0
    service_s = prefill_s + mean_new * decode_s
    return {
        "prefill_p50_ms": prefill_s * 1e3,
        "decode_p50_ms": decode_s * 1e3,
        "service_s": service_s,
        "rate_rps": UTILIZATION * eng.slots / service_s,
        "ttft_slo_s": TTFT_MULT * step_s,
        "itl_slo_s": ITL_MULT * step_s,
        "deadline_s": DEADLINE_MULT * service_s,
    }


def run_wall(quick: bool, trials: int | None, trace: list | None) -> dict:
    """Wall-clock section: per variant, calibrate then run the trial set."""
    n_trials = trials if trials is not None else (2 if quick else 4)
    n_requests = 10 if quick else 32
    int4 = None   # resolved per-variant below (needs cfg.num_layers)
    out = {}
    for name, use_int4, prefix_cache in (
            ("fp32_kv16", False, 0),
            ("int4_kv4", True, 32 << 20)):
        cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
        if use_int4:
            int4 = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                               last_k_int4=cfg.num_layers)
        # the int4 variant pre-warms (DESIGN.md §16): every (bucket, n)
        # prefill/decode shape compiles before traffic, so its lifetime
        # first-step latency sits next to the un-warmed fp32 row's compile
        # spike in the step_latency block below
        eng, cfg = _build_engine(int4 if use_int4 else None,
                                 "pallas" if use_int4 else "reference",
                                 use_int4, 4 if use_int4 else 16,
                                 prefix_cache=prefix_cache,
                                 warmup=use_int4)
        w = Workload(n_requests=n_requests, vocab=cfg.vocab_size,
                     prompt_len=(4, 12), new_tokens=(2, 6),
                     shared_prefix_frac=0.5 if prefix_cache else 0.0,
                     sampled_frac=0.25, priorities=(0, 1),
                     deadline_frac=0.2, cancel_frac=0.2,
                     cancel_after_tokens=2)
        calib = _warmup_and_calibrate(eng, cfg, w)
        w = dataclasses.replace(w, rate_rps=calib["rate_rps"],
                                deadline_s=calib["deadline_s"])
        slo = SLO(ttft_s=calib["ttft_slo_s"], itl_s=calib["itl_slo_s"])
        # ONE engine across trials (fresh engines would recompile the jitted
        # steps every trial and time XLA, not serving); it is drained
        # between trials, so only the prefix cache stays warm — the steady
        # state a long-lived engine actually runs in.
        results = run_trials(lambda: eng, w, n_trials=n_trials,
                             trace=trace)
        # first-vs-steady step latency (lifetime values — they survive the
        # per-trial pop_summary drains): cold-start cost vs steady state
        fin = eng.metrics.summary()
        step_latency = {"warmup": use_int4}
        for kind in ("prefill", "decode"):
            for suffix in ("first_ms", "steady_p50_ms"):
                key = f"{kind}_{suffix}"
                if key in fin:
                    step_latency[key] = fin[key]
        out[name] = {"calibration": calib,
                     "workload": {k: v for k, v in w.__dict__.items()
                                  if not isinstance(v, np.ndarray)},
                     "step_latency": step_latency,
                     "summary": bootstrap_summary(results, slo)}
        g = out[name]["summary"].get("goodput", {})
        print(f"[wall] {name}: goodput {g.get('mean', 0):.3f} "
              f"[{g.get('lo', 0):.3f}, {g.get('hi', 0):.3f}] over "
              f"{n_trials}x{n_requests} requests")
    return out


#: fixed deterministic cost model for the virtual section — NOT calibrated:
#: virtual numbers must be identical on every host.
VCOST = VirtualCost(decode_step_s=0.01, prefill_per_token_s=0.001)

#: virtual scenarios: (name, workload, slo, max_queue)
def _virtual_scenarios(quick: bool, vocab: int) -> list[tuple]:
    n = 12 if quick else 32
    return [
        ("steady",
         Workload(n_requests=n, rate_rps=25.0, vocab=vocab,
                  prompt_len=(4, 12), new_tokens=(2, 6)),
         SLO(ttft_s=0.5, itl_s=0.1), None),
        ("overload_shed",
         Workload(n_requests=n, rate_rps=400.0, vocab=vocab,
                  prompt_len=(4, 12), new_tokens=(4, 8),
                  deadline_frac=1.0, deadline_s=0.05),
         SLO(ttft_s=0.2, itl_s=0.1), 4),
        ("cancel_churn",
         Workload(n_requests=n, rate_rps=50.0, vocab=vocab,
                  prompt_len=(4, 12), new_tokens=(4, 8),
                  cancel_frac=0.6, cancel_after_tokens=3),
         SLO(ttft_s=0.5, itl_s=0.1), None),
    ]


def _bert_encoder_model():
    """Small int4 W4A4 BERT classifier deployed under a mode='encoder' plan
    — the DESIGN.md §14 serving artifact, sized for CPU-virtual runs."""
    bcfg = tinybert_config(num_classes=2, layers=2, d=64, heads=4, d_ff=128,
                           vocab=256, name="tinybert-bench")
    bpol = QuantPolicy(num_layers=bcfg.num_layers, mode="int",
                       last_k_int4=bcfg.num_layers)
    bplan = ExecutionPlan.build(bcfg, bpol, backend="reference", act_bits=4,
                                mode="encoder", prefill_batch=4)
    bparams = init_bert_classifier(bcfg, 2, jax.random.PRNGKey(7))
    return deploy(bparams, bplan)


def run_virtual_encoder(quick: bool) -> dict:
    """Virtual-clock encoder + multi-tenant scenarios (DESIGN.md §14).

    * ``encoder_steady`` — a pure EncodeRequest stream (classify) against a
      mode='encoder' int4 W4A4 engine: prefill-only goodput, deterministic.
    * ``mixed_tenant`` — ONE MultiTenantEngine hosting the encoder artifact
      ('cls', modest offered rate) next to an int4 decoder ('gen', flooded
      past its bounded queue): deficit round-robin must keep the modest
      tenant's SLO goodput high while the flood tenant absorbs its own
      rejections — the fair-share / no-starvation evidence, byte-identical
      across runs like the rest of the virtual section.
    """
    n = 12 if quick else 32
    out = {}

    bmodel = _bert_encoder_model()
    w_enc = Workload(n_requests=n, rate_rps=40.0, vocab=256,
                     prompt_len=(4, 12), encode_frac=1.0)
    slo_enc = SLO(ttft_s=0.3, itl_s=0.1)

    def make_enc():
        return ServingEngine(bmodel, slots=2, max_len=64,
                             clock=VirtualClock())

    results = run_trials(make_enc, w_enc, n_trials=2, cost=VCOST)
    s = bootstrap_summary(results, slo_enc)
    out["encoder_steady"] = {"cost": VCOST.__dict__, "summary": s}
    g = s.get("goodput", {"mean": 0.0})
    print(f"[virtual] encoder_steady: goodput {g['mean']:.3f}, "
          f"completed {s['n_completed']}/{s['n_counted']}")

    # ---- mixed_tenant: flood vs modest through one DRR pump
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    w4_pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                         last_k_int4=cfg.num_layers)
    w4_plan = ExecutionPlan.build(cfg, w4_pol, backend="reference",
                                  act_bits=4)
    lmodel = deploy(api.init_model(cfg, jax.random.PRNGKey(0)), w4_plan)

    w_cls = Workload(n_requests=n, rate_rps=20.0, vocab=256,
                     prompt_len=(4, 12), encode_frac=1.0, tenant="cls")
    w_gen = Workload(n_requests=2 * n, rate_rps=300.0, vocab=cfg.vocab_size,
                     prompt_len=(4, 12), new_tokens=(2, 6), tenant="gen")
    slo_mix = SLO(ttft_s=0.5, itl_s=0.1)

    def make_mt():
        mt = MultiTenantEngine(clock=VirtualClock(), quantum_tokens=32)
        mt.add_tenant("cls", bmodel, slots=2, max_len=64)
        mt.add_tenant("gen", lmodel, slots=2, max_len=64, max_queue=4)
        return mt

    results = []
    for i in range(2):
        arrivals = sorted(
            make_arrivals(w_cls, seed=100 + i)
            + make_arrivals(w_gen, seed=200 + i), key=lambda a: a.t)
        results.append(run_load(make_mt(), arrivals, cost=VCOST))
    s = bootstrap_summary(results, slo_mix)
    out["mixed_tenant"] = {"cost": VCOST.__dict__, "summary": s}
    bt = s.get("by_tenant", {})
    for name, cell in bt.items():
        print(f"[virtual] mixed_tenant/{name}: goodput "
              f"{cell['goodput']:.3f} "
              f"({cell['n_good']}/{cell['n_counted']})")
    return out


def run_paged_capacity(quick: bool) -> dict:
    """Virtual-clock paged-vs-dense capacity scenario (DESIGN.md §15).

    ONE KV byte budget, two layouts: the dense engine preallocates
    ``slots * max_len`` rows, so the budget caps it at 4 slots; the paged
    engine spends the SAME bytes as 8-token blocks allocated per request's
    worst case, so short requests (1 block each) pack many more concurrent
    streams under the identical budget. The scenario bursts short prompts
    at t=0 into both engines, tracks peak concurrency, and checks:

    * goodput 1.0 — every request completes on both layouts;
    * ``capacity_ratio`` = paged/dense peak concurrency (CI gates >= 2x);
    * ``streams_match`` — per-request token streams byte-identical across
      layouts (the §15 bit-identity claim, under load).

    Deterministic like the rest of the virtual section: fixed seeds, fixed
    burst, VirtualClock timing — two runs produce identical JSON."""
    n = 12 if quick else 24
    dense_slots, paged_slots, max_len = 4, 16, 64
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    params = None
    # the ONE budget: exactly what dense preallocates for 4 slots at kv4
    block_bytes = (PREFIX_BLOCK * cfg.num_layers
                   * kv_row_bytes(cfg.num_kv_heads, cfg.hd, 4, fp_bytes=4))
    budget = dense_slots * (max_len // PREFIX_BLOCK) * block_bytes

    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(rng.integers(3, 7))).tolist()
               for _ in range(n)]

    def burst(paging, slots, kv_budget):
        nonlocal params
        plan = ExecutionPlan.build(cfg, pol, backend="reference", kv_bits=4,
                                   kv_paging=paging)
        if params is None:
            params = deploy(api.init_model(cfg, jax.random.PRNGKey(0)),
                            plan).params
        kw = {"kv_budget_bytes": kv_budget} if paging == "paged" else {}
        eng = ServingEngine(params, plan, slots=slots, max_len=max_len,
                            clock=VirtualClock(), **kw)
        # 4 new tokens => requests hold their slot across several pump
        # steps, so post-step concurrency sampling sees the true packing
        streams = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=4))
                   for p in prompts]
        peak = 0
        for _ in range(10_000):
            eng.engine_step()
            peak = max(peak, sum(1 for r in eng.active if r is not None))
            if not (eng.queue or any(r is not None for r in eng.active)):
                break
        done = eng.pop_done()
        toks = [tuple(s.result().tokens) for s in streams]
        good = sum(r.finish_reason == "length" for r in done)
        cell = {"slots": slots, "peak_concurrent": peak,
                "goodput": {"mean": good / n}, "n_requests": n}
        if paging == "paged":
            st = eng.pool.stats()
            cell["kv"] = {k: st[k] for k in
                          ("blocks_total", "block_bytes", "budget_bytes",
                           "cow_forks", "evictions")}
        return cell, toks

    dense_cell, dense_toks = burst("dense", dense_slots, None)
    paged_cell, paged_toks = burst("paged", paged_slots, budget)
    ratio = paged_cell["peak_concurrent"] / max(dense_cell["peak_concurrent"],
                                                1)
    out = {
        "budget_bytes": budget,
        "dense": dense_cell,
        "paged": paged_cell,
        "capacity_ratio": ratio,
        "streams_match": dense_toks == paged_toks,
    }
    print(f"[virtual] paged_capacity: {paged_cell['peak_concurrent']} vs "
          f"{dense_cell['peak_concurrent']} concurrent under "
          f"{budget >> 10}KiB ({ratio:.1f}x), goodput "
          f"{paged_cell['goodput']['mean']:.2f}/"
          f"{dense_cell['goodput']['mean']:.2f}, "
          f"streams_match={out['streams_match']}")
    return out


def run_replica_scale(quick: bool) -> dict:
    """Virtual-clock data-parallel scaling scenario (DESIGN.md §16).

    The same burst — 24 short prompts, 16 new tokens each — served by ONE
    2-slot engine and by a ``ReplicaSet`` of two such engines over the same
    deployed model. Virtual time charges one ``decode_step_s`` per
    ``engine_step()`` (a ReplicaSet pumps every member per step — replicas
    are concurrent hardware) plus ``prefill_per_token_s`` for each prompt
    token first entering service that step, so:

    * ``capacity_ratio`` = single/replicas elapsed virtual time — ideal
      scaling is 2.0; queueing edge effects land it ~1.9 (CI gates >= 1.8);
    * ``streams_match`` — per-request token tuples byte-identical across
      the two runs (dispatch must never influence tokens);
    * goodput 1.0 on both — every request completes.

    Deterministic like the rest of the virtual section: fixed seed, fixed
    burst, fixed costs — two runs produce identical JSON."""
    n, slots, max_len, new_tokens = 24, 2, 64, 16
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                      last_k_int4=cfg.num_layers)
    plan = ExecutionPlan.build(cfg, pol, backend="reference", kv_bits=8)
    model = deploy(api.init_model(cfg, jax.random.PRNGKey(0)), plan)

    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(rng.integers(3, 6))).astype(np.int32)
               for _ in range(n)]

    def burst(make_engine):
        vc = VirtualClock()
        eng = make_engine(vc)
        streams = [eng.submit(GenerationRequest(prompt=p,
                                                max_new_tokens=new_tokens))
                   for p in prompts]
        plen = {s.rid: len(p) for s, p in zip(streams, prompts)}
        seen: set = set()
        for _ in range(10_000):
            events = eng.engine_step()
            # a rid's first event marks its prefill: charge its prompt
            new = {rid for rid, _ in events} - seen
            seen |= new
            vc.advance(VCOST.decode_step_s + VCOST.prefill_per_token_s
                       * sum(plen[r] for r in new))
            if not eng.scheduler.has_work:
                break
        else:
            raise RuntimeError("replica_scale burst did not drain")
        done = eng.pop_done()
        toks = [tuple(s.result().tokens) for s in streams]
        good = sum(r.finish_reason == "length" for r in done)
        return {"elapsed_virtual_s": vc(), "n_requests": n,
                "goodput": {"mean": good / n}}, toks

    single_cell, single_toks = burst(
        lambda vc: ServingEngine(model, slots=slots, max_len=max_len,
                                 clock=vc))
    rep_cell, rep_toks = burst(
        lambda vc: ReplicaSet(model, replicas=2, slots=slots,
                              max_len=max_len, clock=vc))
    ratio = single_cell["elapsed_virtual_s"] / max(
        rep_cell["elapsed_virtual_s"], 1e-9)
    out = {
        "cost": VCOST.__dict__,
        "replica_count": 2,
        "single": single_cell,
        "replicas": rep_cell,
        "capacity_ratio": ratio,
        "streams_match": single_toks == rep_toks,
    }
    print(f"[virtual] replica_scale: {single_cell['elapsed_virtual_s']:.3f}s "
          f"single vs {rep_cell['elapsed_virtual_s']:.3f}s x2 "
          f"({ratio:.2f}x), goodput "
          f"{rep_cell['goodput']['mean']:.2f}/"
          f"{single_cell['goodput']['mean']:.2f}, "
          f"streams_match={out['streams_match']}")
    return out


def run_virtual(quick: bool) -> dict:
    """Virtual-clock section: deterministic goodput/shed/reject numbers.

    Besides the fp reference plan, the steady scenario is repeated on a
    deployed W4A4 engine (int4 weights, act_bits=4 — DESIGN.md §13): the
    virtual cost model keeps the timing identical by construction, so the
    row verifies the int4×int4 serving loop schedules and completes exactly
    like fp — and its determinism rides the same back-to-back byte-equality
    CI check. Informational, never gated."""
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    plan = ExecutionPlan.build(cfg, None, backend="reference")
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    w4_pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                         last_k_int4=cfg.num_layers)
    w4_plan = ExecutionPlan.build(cfg, w4_pol, backend="reference",
                                  act_bits=4)
    w4_params = deploy(api.init_model(cfg, jax.random.PRNGKey(0)),
                       w4_plan).params
    scenarios = _virtual_scenarios(quick, cfg.vocab_size)
    steady_w, steady_slo, steady_q = next(
        (w, slo, q) for n, w, slo, q in scenarios if n == "steady")
    runs = ([(n, plan, params, w, slo, q) for n, w, slo, q in scenarios]
            + [("steady_w4a4", w4_plan, w4_params, steady_w, steady_slo,
                steady_q)])
    out = {}
    for name, sc_plan, sc_params, w, slo, max_queue in runs:
        def make_engine():
            return ServingEngine(sc_params, sc_plan, slots=2, max_len=64,
                                 max_queue=max_queue, clock=VirtualClock())
        results = run_trials(make_engine, w, n_trials=2, cost=VCOST)
        s = bootstrap_summary(results, slo)
        out[name] = {"cost": VCOST.__dict__, "summary": s}
        g = s.get("goodput", {"mean": 0.0})
        print(f"[virtual] {name}: goodput {g['mean']:.3f}, "
              f"shed {s['n_shed']}, rejected {s['n_rejected']}, "
              f"cancelled {s['n_cancelled']}")
    return out


def main(quick: bool = False, trials: int | None = None,
         trace_path: str | None = None,
         out: str | None = "BENCH_load.json") -> None:
    trace = load_trace(trace_path) if trace_path else None
    wall = run_wall(quick, trials, trace)
    virtual = run_virtual(quick)
    virtual.update(run_virtual_encoder(quick))
    virtual["paged_capacity"] = run_paged_capacity(quick)
    virtual["replica_scale"] = run_replica_scale(quick)
    if out:
        payload = {
            "bench": "serve_load",
            "quick": quick,
            "trace": trace_path,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "wall": wall,
            "virtual": virtual,
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[serve_load] wrote {out}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--trials", type=int, default=None,
                   help="override the wall-mode trial count")
    p.add_argument("--trace", default=None,
                   help="recorded-trace JSON to replay in wall mode")
    p.add_argument("--out", default="BENCH_load.json",
                   help="machine-readable results path ('' to skip)")
    a = p.parse_args()
    main(quick=a.quick, trials=a.trials, trace_path=a.trace,
         out=a.out or None)
