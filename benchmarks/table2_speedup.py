"""Table 2 reproduction: one-transformer-layer inference time,
float32 vs int8 vs int4 (paper: 15x / 1.25x on T4).

Two views are reported (the container is CPU-only; TPU v5e is the target):

  * measured CPU wall-clock of the jnp execution paths (fp32 matmul vs the
    int8-dot path vs packed-int4-unpack-dot path) — demonstrates the
    end-to-end deployed pipeline really runs;
  * DERIVED TPU roofline latency from the bytes/FLOPs each layer moves
    (decode regime, weight-bandwidth-bound — exactly the paper's win):
    t = max(weight_bytes / 819 GB/s, flops / peak). This is the number
    comparable to the paper's Table 2 ratios.

Rows mirror the paper's (batch, valid-token) grid scaled to BERT-base dims.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.qat import calibrate_weight_scales
from repro.models.layers import QuantSpec
from repro.models.transformer import block_apply

HBM = 819e9
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12


def _layer_params(cfg, mode, bits, key):
    from repro.models.transformer import init_block
    p = init_block(key, cfg, stacked=None)
    if mode != "none":
        def bf(prefix):
            return np.float32(bits)
        p = {"layers": p}
        p = calibrate_weight_scales(p, bf)["layers"]
    return p


def _bytes_per_layer(cfg, bits):
    """weight bytes one decode step streams for one layer."""
    d, f, H, Hkv, hd = (cfg.d_model, cfg.d_ff, cfg.num_heads,
                        cfg.num_kv_heads, cfg.hd)
    n_params = d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d \
        + 2 * d * f  # gelu ffn: w1, w2
    return n_params * (bits / 8 if bits else 4)


def _flops_per_layer(cfg, tokens):
    d, f, H, hd = cfg.d_model, cfg.d_ff, cfg.num_heads, cfg.hd
    n_params = d * (H * hd) * 2 + 2 * d * (cfg.num_kv_heads * hd) + 2 * d * f
    return 2 * n_params * tokens


def measure(cfg, mode, bits, batch, seq, iters=10):
    key = jax.random.PRNGKey(0)
    p = _layer_params(cfg, mode, bits, key)
    spec = QuantSpec(mode=mode, w_bits=bits or 0, a_bits=bits or 0)
    if mode == "int":
        from repro.core.qat import _quantize_stack
        p = _quantize_stack(p, bits)
    x = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)

    @jax.jit
    def f(p, x):
        out, _, _, _ = block_apply(x, p, cfg, spec)
        return out

    f(p, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(p, x).block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / iters


def main(quick=False):
    cfg = get_config("bert-base").replace(dtype="float32", remat=False)
    # paper grid (batch x valid tokens, prefill regime) + decode-regime rows
    # (seq=1) where the paper's int4 deployment is weight-bandwidth-bound —
    # the regime the 15x CUDA-vs-fp32 figure maps onto for TPU.
    grid = [(4, 110), (4, 168), (16, 1)] if quick else [
        (16, 110), (16, 168), (64, 26), (64, 36), (16, 1), (64, 1)]
    print("table2,name,us_per_call,derived")
    for batch, seq in grid:
        tokens = batch * seq
        row = {}
        for name, mode, bits in [("float32", "none", 0), ("int8", "int", 8),
                                 ("int4", "int", 4)]:
            us = measure(cfg, mode, bits, batch, seq,
                         iters=3 if quick else 10)
            # TPU decode-regime roofline latency for this layer
            wb = _bytes_per_layer(cfg, bits)
            fl = _flops_per_layer(cfg, tokens)
            peak = PEAK_INT8 if bits else PEAK_BF16
            t_roof = max(wb / HBM, fl / peak) * 1e6
            row[name] = (us, t_roof)
            print(f"table2,bs{batch}_tok{tokens}_{name},{us:.1f},"
                  f"roofline_us={t_roof:.2f}")
        for a, b in [("float32", "int4"), ("int8", "int4")]:
            cpu_ratio = row[a][0] / row[b][0]
            roof_ratio = row[a][1] / row[b][1]
            print(f"table2,bs{batch}_tok{tokens}_speedup_{a}_over_int4,"
                  f"{cpu_ratio:.2f},tpu_roofline_ratio={roof_ratio:.2f}")


if __name__ == "__main__":
    main()
