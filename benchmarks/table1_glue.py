"""Table 1 reproduction: quality vs int4-layer count, MKQ-BERT vs KDLSQ.

The container is offline, so GLUE is replaced by the deterministic synthetic
classification task (repro.data) — same pipeline, swappable data. Rows follow
the paper: TinyBERT4 with the last {1,2,3,4} layers int4 (rest int8), each
trained with (a) MKQ-BERT (MSE scale grads + MINI distill + true k-bit acts)
and (b) the KDLSQ baseline (STE scale grads, int8 acts, output-KD only).

Paper claim being validated: MKQ >= KDLSQ at every compression level, with
the gap widening as more layers go to 4 bits (Table 1's 2-3-4 rows).

``--artifact DIR`` runs the DEPLOYED quality bench instead (DESIGN.md §13):
train an fp student, calibrate, deploy a W4A4 artifact through the real
export → save → load path, and score the cold artifact against the fp
reference on the same task — the paper's "no accuracy loss at W4A4" claim
measured on what serving actually runs, not on fake-quant training graphs.
Emits ``BENCH_quality.json`` (gated in CI by tools/check_quality.py) and
runs the sensitivity-ranked mixed-precision auto-search
(repro.core.autosearch) for the cheapest per-layer bit assignment meeting
an accuracy floor.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.models import api
from repro.models.bert import bert_classify_logits, init_bert_classifier

from . import common


def run(steps=150, seed=0, rows=(1, 2, 3, 4), quick=False):
    if quick:
        steps, rows = 80, (2, 4)
    cfg = common.student_config()
    tcfg = common.teacher_config()
    data = common.make_task(seed=seed).it if hasattr(
        common.make_task(seed=seed), "it") else None
    from repro.data.synthetic import SyntheticClassification
    data = SyntheticClassification(cfg.vocab_size, 24, 64,
                                   num_classes=common.NUM_CLASSES, seed=seed)

    key = jax.random.PRNGKey(seed)
    # 1) teacher: deeper fp model, trained on the task
    tsegs = api.segments_for(tcfg, None)
    teacher = common.train_best(
        lambda: init_bert_classifier(tcfg, common.NUM_CLASSES, key),
        tcfg, tsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))
    t_acc = common.evaluate(teacher, tcfg, tsegs, data)

    # 2) fp student baseline ("TinyBERT4 (original)" row)
    fsegs = api.segments_for(cfg, None)
    fp_student = common.train_best(
        lambda: init_bert_classifier(cfg, common.NUM_CLASSES,
                                     jax.random.fold_in(key, 1)),
        cfg, fsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))
    fp_acc = common.evaluate(fp_student, cfg, fsegs, data)
    results = [("teacher_fp32", "-", t_acc), ("student_fp32", "-", fp_acc)]

    for k4 in rows:
        for algo in ("mkq", "kdlsq"):
            pol = QuantPolicy(
                num_layers=cfg.num_layers, mode="fake", last_k_int4=k4,
                grad_mode="mse" if algo == "mkq" else "ste",
                act_bits_override=None if algo == "mkq" else 8)
            segs = api.segments_for(cfg, pol)
            calibrated = common.build_qat_student(cfg, pol, data,
                                                  fp_student)
            params = common.train_best(
                lambda: calibrated, cfg, segs, data, steps=steps,
                lrs=(1e-3, 5e-4), teacher=teacher, teacher_cfg=tcfg,
                teacher_segments=tsegs, use_mini_kd=(algo == "mkq"),
                use_output_kd=True)
            acc = common.evaluate(params, cfg, segs, data)
            results.append((f"tinybert4_int4x{k4}", algo, acc))
    return results


def main(quick=False):
    t0 = time.perf_counter()
    results = run(quick=quick)
    dt_us = (time.perf_counter() - t0) * 1e6
    print("table1,name,algo,accuracy")
    for name, algo, acc in results:
        print(f"table1,{name},{algo},{acc:.4f}")
    # paper-shaped assertions reported as derived values
    by = {(n, a): acc for n, a, acc in results}
    rows = sorted({int(n.split("x")[1]) for n, a, _ in results
                   if "int4" in n})
    wins = sum(by[(f"tinybert4_int4x{k}", "mkq")]
               >= by[(f"tinybert4_int4x{k}", "kdlsq")] for k in rows)
    print(f"table1,mkq_wins_over_kdlsq,derived,{wins}/{len(rows)}")
    print(f"table1,total,us_per_call,{dt_us:.0f}")
    return results


# ------------------------------------------------- deployed quality bench

def _preds(params, plan, data, n_batches, offset=10_000):
    """Argmax predictions through an ExecutionPlan — the same plan-routed
    forward the serving encoder path runs (DESIGN.md §14)."""
    out = []
    for i in range(n_batches):
        b = data.batch(offset + i)
        logits, _ = bert_classify_logits(params, plan,
                                         jnp.asarray(b["tokens"]))
        out.append(np.asarray(jnp.argmax(logits, -1)))
    return np.concatenate(out)


def run_artifact(quick=False, artifact_dir=None, search=True, seed=0):
    """Train fp student → calibrate → deploy W4A4 → save → load → score.

    Returns the BENCH_quality.json payload (DESIGN.md §13). All randomness
    is seeded, so two back-to-back runs on one host agree exactly — the CI
    flap check relies on this; the committed baseline carries a tolerance
    band for cross-host float drift instead.
    """
    import tempfile

    from repro.core.autosearch import (cached_probe_scorer,
                                       search_mixed_precision)
    from repro.data.synthetic import SyntheticClassification
    from repro.deploy import (DeployedModel, ExecutionPlan, deploy,
                              retarget_act_bits)

    steps = 80 if quick else 200
    n_eval = 8
    cfg = common.student_config()
    data = SyntheticClassification(cfg.vocab_size, 24, 64,
                                   num_classes=common.NUM_CLASSES, seed=seed)
    key = jax.random.PRNGKey(seed)

    fp_plan = ExecutionPlan.build(cfg, None, backend="reference")
    fsegs = fp_plan.segments
    fp_student = common.train_best(
        lambda: init_bert_classifier(cfg, common.NUM_CLASSES, key),
        cfg, fsegs, data, steps=steps,
        lrs=(2e-3,) if quick else (2e-3, 1e-3))
    fp_acc = common.evaluate(fp_student, cfg, fsegs, data,
                             n_batches=n_eval)
    fp_pred = _preds(fp_student, fp_plan, data, n_eval)

    calib = [data.batch(5000 + i) for i in range(2 if quick else 4)]

    def deploy_policy(policy, act_bits=None, save_dir=None):
        plan = ExecutionPlan.build(cfg, policy, backend="reference",
                                   act_bits=act_bits)
        model = deploy(fp_student, plan, calib)
        if save_dir:   # the real serving path: cold artifact from disk
            model.save(save_dir)
            model = DeployedModel.load(save_dir)
        return model

    def score_model(model):
        return common.evaluate(model.params, cfg, model.plan.segments,
                               data, n_batches=n_eval)

    # --- the headline row: every layer W4A4, scored from a cold artifact
    w4_pol = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                         last_k_int4=cfg.num_layers)
    if artifact_dir is None:
        artifact_dir = tempfile.mkdtemp(prefix="mkq-quality-")
    w4a4 = deploy_policy(w4_pol, act_bits=4, save_dir=artifact_dir)
    w4a4_acc = score_model(w4a4)
    w4a4_pred = _preds(w4a4.params, w4a4.plan, data, n_eval)
    agreement = float((w4a4_pred == fp_pred).mean())

    # weight-only parity row: same codes, fp activations (the integer-accum
    # path's reference — isolates activation-quant error from weight error)
    wfp = retarget_act_bits(w4a4, 0)
    wfp_acc = score_model(wfp)

    payload = {"quality": {
        "fp_acc": fp_acc, "w4a4_acc": w4a4_acc,
        "weight_only_acc": wfp_acc, "delta": fp_acc - w4a4_acc,
        "agreement": agreement, "act_bits": 4,
        "n_eval": int(n_eval * 64), "artifact": artifact_dir}}

    if search:
        # relative floor: "within 5 accuracy points of the fp student".
        # The cheap probe (DESIGN.md §13) deploys only the two uniform
        # grids and assembles every candidate by slicing them — each probe
        # costs an eval, not a re-deploy.
        cheap = cached_probe_scorer(deploy_policy, score_model)
        res = search_mixed_precision(cfg.num_layers, cheap,
                                     floor_delta=0.05, fp_score=fp_acc)

        # bit-exactness gate: the cheap probe must rank layers IDENTICALLY
        # to the full re-deploy probe (same drops, not just same order) —
        # the assembled slices are the same packed bytes a full deploy
        # produces, so any divergence is a real bug, not noise.
        def full(int4_layers):
            return score_model(deploy_policy(QuantPolicy(
                num_layers=cfg.num_layers, mode="int",
                int4_layers=tuple(int4_layers))))

        base_full = full(())
        full_rank = tuple(sorted(
            ((l, base_full - full((l,))) for l in range(cfg.num_layers)),
            key=lambda t: (t[1], t[0])))
        if full_rank != res.sensitivity:
            raise AssertionError(
                f"cheap probe diverged from full probe: "
                f"cheap={res.sensitivity} full={full_rank}")
        payload["search"] = {
            "probe_check": {"ranks_match": True,
                            "base_matches": base_full == res.base_accuracy},
            "floor": res.floor,
            "base_int8_acc": res.base_accuracy,
            "chosen_int4_layers": sorted(res.policy.int4_layers or ()),
            "accuracy": res.accuracy,
            "sensitivity": [[l, d] for l, d in res.sensitivity],
            "trajectory": [[list(ls), acc, ok]
                           for ls, acc, ok in res.trajectory]}
    return payload


def main_artifact(quick=False, artifact_dir=None, out=None, search=True):
    t0 = time.perf_counter()
    payload = run_artifact(quick=quick, artifact_dir=artifact_dir,
                           search=search)
    q = payload["quality"]
    print("quality,metric,value")
    for k in ("fp_acc", "w4a4_acc", "weight_only_acc", "delta",
              "agreement"):
        print(f"quality,{k},{q[k]:.4f}")
    if "search" in payload:
        s = payload["search"]
        print(f"quality,search_int4_layers,"
              f"\"{s['chosen_int4_layers']}\"")
        print(f"quality,search_acc,{s['accuracy']:.4f}")
    print(f"quality,total_s,{time.perf_counter() - t0:.1f}")
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"quality,json,{out}")
    return payload


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--artifact", default=None, metavar="DIR", nargs="?",
                   const="", help="deployed-quality mode: export the W4A4 "
                   "artifact to DIR (temp dir when omitted), score it cold "
                   "against the fp reference, run the mixed-precision "
                   "search, and emit --out JSON")
    p.add_argument("--out", default=None, metavar="JSON",
                   help="artifact mode: write BENCH_quality.json here")
    p.add_argument("--no-search", action="store_true",
                   help="artifact mode: skip the mixed-precision search")
    a = p.parse_args()
    if a.artifact is not None:
        main_artifact(quick=a.quick, artifact_dir=a.artifact or None,
                      out=a.out, search=not a.no_search)
    else:
        main(quick=a.quick)
