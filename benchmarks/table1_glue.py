"""Table 1 reproduction: quality vs int4-layer count, MKQ-BERT vs KDLSQ.

The container is offline, so GLUE is replaced by the deterministic synthetic
classification task (repro.data) — same pipeline, swappable data. Rows follow
the paper: TinyBERT4 with the last {1,2,3,4} layers int4 (rest int8), each
trained with (a) MKQ-BERT (MSE scale grads + MINI distill + true k-bit acts)
and (b) the KDLSQ baseline (STE scale grads, int8 acts, output-KD only).

Paper claim being validated: MKQ >= KDLSQ at every compression level, with
the gap widening as more layers go to 4 bits (Table 1's 2-3-4 rows).
"""
from __future__ import annotations

import time

import jax

from repro.core.policy import QuantPolicy
from repro.models import api
from repro.models.bert import init_bert_classifier

from . import common


def run(steps=150, seed=0, rows=(1, 2, 3, 4), quick=False):
    if quick:
        steps, rows = 80, (2, 4)
    cfg = common.student_config()
    tcfg = common.teacher_config()
    data = common.make_task(seed=seed).it if hasattr(
        common.make_task(seed=seed), "it") else None
    from repro.data.synthetic import SyntheticClassification
    data = SyntheticClassification(cfg.vocab_size, 24, 64,
                                   num_classes=common.NUM_CLASSES, seed=seed)

    key = jax.random.PRNGKey(seed)
    # 1) teacher: deeper fp model, trained on the task
    tsegs = api.segments_for(tcfg, None)
    teacher = common.train_best(
        lambda: init_bert_classifier(tcfg, common.NUM_CLASSES, key),
        tcfg, tsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))
    t_acc = common.evaluate(teacher, tcfg, tsegs, data)

    # 2) fp student baseline ("TinyBERT4 (original)" row)
    fsegs = api.segments_for(cfg, None)
    fp_student = common.train_best(
        lambda: init_bert_classifier(cfg, common.NUM_CLASSES,
                                     jax.random.fold_in(key, 1)),
        cfg, fsegs, data, steps=2 * steps, lrs=(2e-3, 1e-3, 5e-4))
    fp_acc = common.evaluate(fp_student, cfg, fsegs, data)
    results = [("teacher_fp32", "-", t_acc), ("student_fp32", "-", fp_acc)]

    for k4 in rows:
        for algo in ("mkq", "kdlsq"):
            pol = QuantPolicy(
                num_layers=cfg.num_layers, mode="fake", last_k_int4=k4,
                grad_mode="mse" if algo == "mkq" else "ste",
                act_bits_override=None if algo == "mkq" else 8)
            segs = api.segments_for(cfg, pol)
            calibrated = common.build_qat_student(cfg, pol, data,
                                                  fp_student)
            params = common.train_best(
                lambda: calibrated, cfg, segs, data, steps=steps,
                lrs=(1e-3, 5e-4), teacher=teacher, teacher_cfg=tcfg,
                teacher_segments=tsegs, use_mini_kd=(algo == "mkq"),
                use_output_kd=True)
            acc = common.evaluate(params, cfg, segs, data)
            results.append((f"tinybert4_int4x{k4}", algo, acc))
    return results


def main(quick=False):
    t0 = time.perf_counter()
    results = run(quick=quick)
    dt_us = (time.perf_counter() - t0) * 1e6
    print("table1,name,algo,accuracy")
    for name, algo, acc in results:
        print(f"table1,{name},{algo},{acc:.4f}")
    # paper-shaped assertions reported as derived values
    by = {(n, a): acc for n, a, acc in results}
    rows = sorted({int(n.split("x")[1]) for n, a, _ in results
                   if "int4" in n})
    wins = sum(by[(f"tinybert4_int4x{k}", "mkq")]
               >= by[(f"tinybert4_int4x{k}", "kdlsq")] for k in rows)
    print(f"table1,mkq_wins_over_kdlsq,derived,{wins}/{len(rows)}")
    print(f"table1,total,us_per_call,{dt_us:.0f}")
    return results


if __name__ == "__main__":
    main()
