"""Shared benchmark utilities: the mini QAT pipeline used by table1/table3."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import qat
from repro.core.distill import (combine_losses, minilm_losses, output_loss)
from repro.data import classification_batches
from repro.models import api
from repro.models.bert import bert_classify_logits, classification_loss
from repro.optim import adam_init, adam_update, linear_warmup_decay

NUM_CLASSES = 2


def student_config(num_layers=4):
    return reduced(get_config("tinybert4")).replace(
        num_layers=num_layers, d_model=96, num_heads=4, num_kv_heads=4,
        d_ff=192, vocab_size=512)


def teacher_config():
    # deeper teacher (MINI distill needs no layer mapping)
    return student_config(num_layers=6).replace(d_model=128, num_heads=8,
                                                num_kv_heads=8, d_ff=256)


def make_task(seed=0, seq=24, batch=64):
    cfg = student_config()
    return classification_batches(cfg.vocab_size, seq, batch,
                                  num_classes=NUM_CLASSES, seed=seed,
                                  prefetch=False)


def evaluate(params, cfg, segments, data, n_batches=8, offset=10_000):
    correct = total = 0
    for i in range(n_batches):
        b = data.batch(offset + i)
        logits, _ = bert_classify_logits(
            params, cfg, segments, jnp.asarray(b["tokens"]))
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def train_classifier(params, cfg, segments, data, *, steps, lr=3e-3,
                     teacher=None, teacher_cfg=None, teacher_segments=None,
                     alpha=10.0, beta=1.0, use_output_kd=True,
                     use_mini_kd=True, freeze_scales=False, seed=0):
    """QAT (or fp) classifier training with optional MINI distillation."""
    opt = adam_init(params)
    sched = linear_warmup_decay(steps, 0.1)
    lr_by_group = {"weights": lr, "act_scale": 0.0 if freeze_scales else 0.01,
                   "weight_scale": 0.0 if freeze_scales else 0.001}
    distill = teacher is not None

    def loss_fn(p, toks, labels):
        logits, taps_s = bert_classify_logits(p, cfg, segments, toks,
                                              want_taps=distill)
        l_train = classification_loss(logits, labels)
        if not distill:
            return l_train
        t_logits, taps_t = bert_classify_logits(teacher, teacher_cfg,
                                                teacher_segments, toks,
                                                want_taps=True)
        taps_t = jax.lax.stop_gradient(taps_t)
        l_out = output_loss(logits, jax.lax.stop_gradient(t_logits)) \
            if use_output_kd else jnp.zeros(())
        if use_mini_kd:
            l_attn, l_val = minilm_losses(taps_s, taps_t,
                                          num_relation_heads=4)
        else:
            l_attn = l_val = jnp.zeros(())
        total, _ = combine_losses(l_train, l_out, l_attn, l_val, alpha, beta)
        return total

    @jax.jit
    def step(p, o, toks, labels):
        l, g = jax.value_and_grad(loss_fn)(p, toks, labels)
        p, o = adam_update(p, g, o, lr_by_group=lr_by_group,
                           schedule_fn=sched, grad_clip=1.0)
        return p, o, l

    for i in range(steps):
        b = data.batch(i)
        params, opt, _ = step(params, opt, jnp.asarray(b["tokens"]),
                              jnp.asarray(b["labels"]))
    return params


def train_best(make_params, cfg, segments, data, *, steps, lrs,
               eval_batches=4, **kw):
    """Paper SS5.2 protocol: sweep the lr grid, keep the best dev result
    (post-LN BERT training is seed/lr sensitive; the paper reports the best
    over all hyperparameters)."""
    best, best_acc = None, -1.0
    for lr in lrs:
        params = train_classifier(make_params(), cfg, segments,
                                  data, steps=steps, lr=lr, **kw)
        acc = evaluate(params, cfg, segments, data, n_batches=eval_batches,
                       offset=20_000)
        if acc > best_acc:
            best, best_acc = params, acc
    return best


def build_qat_student(cfg, policy, data, fp_params, calib_batches=4):
    """Calibrate fp params for the given policy (weights + activations)."""
    params = qat.calibrate_weight_scales(
        fp_params, qat.default_bits_fn(cfg, policy))
    fp_segs = api.segments_for(cfg, None)
    fwd = lambda p, b: bert_classify_logits(p, cfg, fp_segs,
                                            jnp.asarray(b["tokens"]))[0]
    batches = [data.batch(5000 + i) for i in range(calib_batches)]
    return qat.calibrate_act_scales(params, cfg, policy, fwd, batches)


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, iters):
        return (time.perf_counter() - self.t0) * 1e6 / iters
