"""Quickstart: the whole MKQ-BERT pipeline in one script, CPU-sized.

  fp model -> calibrate (abs-max weights, percentile acts)
           -> QAT (LSQ with MSE-based scale gradients, last half int4)
           -> deploy() packed int4/int8 DeployedModel -> verify int parity
           -> save/load the artifact -> generate from the loaded model.

All execution choices (segments, kernels, KV precision, decode dtype) live
in an ``ExecutionPlan`` (repro.deploy, DESIGN.md §9); the deployed weights +
plan round-trip disk as a ``DeployedModel`` artifact.

Run:  PYTHONPATH=src python examples/quickstart.py [--quick]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import qat
from repro.core.policy import QuantPolicy
from repro.data import lm_batches
from repro.deploy import DeployedModel, ExecutionPlan, deploy
from repro.models import api
from repro.models.transformer import lm_loss
from repro.optim import adam_init, adam_update, linear_warmup_decay


def main(quick: bool = False):
    cfg = reduced(get_config("stablelm-3b"))
    n = cfg.num_layers
    qat_steps = 6 if quick else 30
    print(f"model: {cfg.name} (reduced) {n} layers, d={cfg.d_model}")

    # --- plans: paper's best policy — last 50% of layers int4, rest int8.
    # One plan per phase; each resolves segments/kernel choices up front.
    policy = QuantPolicy(num_layers=n, mode="fake", last_k_int4=n // 2,
                         grad_mode="mse")
    qat_plan = ExecutionPlan.build(cfg, policy)
    fp_plan = ExecutionPlan.build(cfg, None)
    print("policy:", policy.describe())

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    data = lm_batches(cfg.vocab_size, 32, 8, prefetch=False)

    # --- calibration (paper §3.1)
    params = qat.calibrate_weight_scales(params,
                                         qat.default_bits_fn(cfg, policy))
    fwd = lambda p, b: api.forward(p, fp_plan,
                                   tokens=jnp.asarray(b["tokens"]))[0]
    it = iter(data)
    params = qat.calibrate_act_scales(params, cfg, policy, fwd,
                                      [next(it) for _ in range(3)])
    print("calibrated weight + activation scales")

    # --- QAT with LSQ-MSE scale gradients
    opt = adam_init(params)
    sched = linear_warmup_decay(qat_steps, 0.1)

    @jax.jit
    def step(p, o, toks, labels):
        def loss_fn(pp):
            logits, _, _, aux = api.forward(pp, qat_plan, tokens=toks)
            return lm_loss(logits, labels) + aux
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adam_update(p, g, o, lr_by_group={"weights": 1e-3,
                                                 "act_scale": 0.01,
                                                 "weight_scale": 0.001},
                           schedule_fn=sched, grad_clip=1.0)
        return p, o, loss

    for i in range(qat_steps):
        b = next(it)
        params, opt, loss = step(params, opt, jnp.asarray(b["tokens"]),
                                 jnp.asarray(b["labels"]))
        if i % 10 == 0:
            print(f"QAT step {i:3d} loss {float(loss):.4f}")

    # --- deploy: pack int4 nibbles / int8 codes into a DeployedModel.
    # recalibrate=False keeps the LEARNED LSQ scales (train==deploy parity).
    int_policy = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    int_plan = ExecutionPlan.build(cfg, int_policy)
    model = deploy(params, int_plan, recalibrate=False)
    wq = model.params["layers"][1]["ffn"]["w1"]["wq"]
    print(f"deployed: int4 packed ffn.w1 {wq.shape} {wq.dtype} "
          f"({wq.size * wq.dtype.itemsize} bytes vs "
          f"{np.prod(params['layers']['ffn']['w1']['w'].shape[1:]) * (n // 2) * 4} fp32)")

    # --- parity: deployed int path == QAT fake-quant path
    b = next(it)
    toks = jnp.asarray(b["tokens"])
    lf, *_ = api.forward(params, qat_plan, tokens=toks)
    li, *_ = api.forward(model.params, int_plan, tokens=toks)
    rel = float(jnp.max(jnp.abs(lf - li)) / jnp.max(jnp.abs(lf)))
    print(f"fake-vs-int parity: rel err {rel:.2e} (expect < 1e-4)")
    assert rel < 1e-4

    # --- artifact round trip: serve runs load this, never the fp weights
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        path = model.save(f"{td}/artifact")
        loaded = DeployedModel.load(path)
    assert loaded.plan.segments == int_plan.segments
    print("artifact save/load round trip OK")

    # --- generation through the streaming API (DESIGN.md §10): greedy is
    # temperature=0 (the default); tokens arrive as the engine produces them
    from repro.serving import GenerationRequest, SamplingParams
    eng = loaded.engine(slots=1, max_len=64)
    stream = eng.submit(GenerationRequest(prompt=np.array([5], np.int32),
                                          max_new_tokens=12))
    out = [tok for tok in stream]          # iterator form pumps the engine
    print("int4/int8 greedy stream:", out)
    sampled = eng.submit(GenerationRequest(
        prompt=np.array([5], np.int32), max_new_tokens=12,
        sampling=SamplingParams(temperature=0.9, top_p=0.95, seed=1)))
    print("int4/int8 sampled stream:", sampled.result().tokens.tolist())
    eng.pop_done()
    print("quickstart complete.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: fewer QAT steps")
    main(quick=ap.parse_args().quick)
