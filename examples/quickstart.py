"""Quickstart: the whole MKQ-BERT pipeline in one script, CPU-sized.

  fp model -> calibrate (abs-max weights, percentile acts)
           -> QAT (LSQ with MSE-based scale gradients, last half int4)
           -> deploy packed int4/int8 -> verify int parity -> generate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import qat
from repro.core.policy import QuantPolicy
from repro.data import lm_batches
from repro.models import api
from repro.models.transformer import lm_loss
from repro.optim import adam_init, adam_update, linear_warmup_decay


def main():
    cfg = reduced(get_config("stablelm-3b"))
    n = cfg.num_layers
    print(f"model: {cfg.name} (reduced) {n} layers, d={cfg.d_model}")

    # --- policy: paper's best config — last 50% of layers int4, rest int8
    policy = QuantPolicy(num_layers=n, mode="fake", last_k_int4=n // 2,
                         grad_mode="mse")
    segments = api.segments_for(cfg, policy)
    print("policy:", policy.describe())

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    data = lm_batches(cfg.vocab_size, 32, 8, prefetch=False)

    # --- calibration (paper §3.1)
    params = qat.calibrate_weight_scales(params,
                                         qat.default_bits_fn(cfg, policy))
    fp_segs = api.segments_for(cfg, None)
    fwd = lambda p, b: api.forward(p, cfg, fp_segs,
                                   tokens=jnp.asarray(b["tokens"]))[0]
    it = iter(data)
    params = qat.calibrate_act_scales(params, cfg, policy, fwd,
                                      [next(it) for _ in range(3)])
    print("calibrated weight + activation scales")

    # --- QAT with LSQ-MSE scale gradients
    opt = adam_init(params)
    sched = linear_warmup_decay(30, 0.1)

    @jax.jit
    def step(p, o, toks, labels):
        def loss_fn(pp):
            logits, _, _, aux = api.forward(pp, cfg, segments, tokens=toks)
            return lm_loss(logits, labels) + aux
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adam_update(p, g, o, lr_by_group={"weights": 1e-3,
                                                 "act_scale": 0.01,
                                                 "weight_scale": 0.001},
                           schedule_fn=sched, grad_clip=1.0)
        return p, o, loss

    for i in range(30):
        b = next(it)
        params, opt, loss = step(params, opt, jnp.asarray(b["tokens"]),
                                 jnp.asarray(b["labels"]))
        if i % 10 == 0:
            print(f"QAT step {i:3d} loss {float(loss):.4f}")

    # --- deploy: pack int4 nibbles / int8 codes
    int_policy = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    int_segments = api.segments_for(cfg, int_policy)
    deployed = qat.deploy_params(params, cfg, int_segments)
    wq = deployed["layers"][1]["ffn"]["w1"]["wq"]
    print(f"deployed: int4 packed ffn.w1 {wq.shape} {wq.dtype} "
          f"({wq.size * wq.dtype.itemsize} bytes vs "
          f"{np.prod(params['layers']['ffn']['w1']['w'].shape[1:]) * (n // 2) * 4} fp32)")

    # --- parity: deployed int path == QAT fake-quant path
    b = next(it)
    toks = jnp.asarray(b["tokens"])
    lf, *_ = api.forward(params, cfg, segments, tokens=toks)
    li, *_ = api.forward(deployed, cfg, int_segments, tokens=toks)
    rel = float(jnp.max(jnp.abs(lf - li)) / jnp.max(jnp.abs(lf)))
    print(f"fake-vs-int parity: rel err {rel:.2e} (expect < 1e-4)")
    assert rel < 1e-4

    # --- greedy generation with the int4/int8 model
    state = api.decode_state(cfg, 1, 64, dtype=jnp.float32)
    tok = jnp.asarray([[5]], jnp.int32)
    out = []
    for _ in range(12):
        logits, state, _, _ = api.forward(deployed, cfg, int_segments,
                                          state=state, tokens=tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("int4/int8 greedy sample:", out)
    print("quickstart complete.")


if __name__ == "__main__":
    main()
