"""Tensor-parallel + replicated int4 serving (DESIGN.md §16).

Both scale axes on one script:

* **tp** — ``ExecutionPlan.build(..., tp=2)`` shards the packed int
  weights column/row-parallel over a 2-device ``"model"`` mesh (weight
  scales follow their out dim, int4 codes shard their packed K/2 nibble
  rows, the sampler inputs stay replicated). The artifact records the
  layout, and ``DeployedModel.load(dir, tp=N)`` reshards it on load —
  here the tp=2 artifact is reloaded at tp=1 AND tp=4 and all three
  layouts must emit byte-identical token streams: int32 accumulation
  makes the row-parallel partial sums exact, so sharding is a pure
  layout decision, never a numerics decision.
* **replicas** — ``ReplicaSet(model, replicas=2)`` runs two engines over
  the SAME deployed arrays behind one admission queue (least-loaded
  dispatch, shared rid space); its streams match a single engine's too.

Needs several XLA devices — on CPU, force them:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python examples/serve_sharded.py [--quick]

(If the host exposes fewer than 2 devices the tp half is skipped with a
note; the replica half runs anywhere.)
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import DeployedModel, ExecutionPlan, deploy
from repro.models import api
from repro.serving import GenerationRequest, ReplicaSet, ServingEngine


def _burst(eng, cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    streams = []
    for _ in range(n):
        plen = int(rng.integers(4, 12))
        streams.append(eng.submit(GenerationRequest(
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=8)))
    eng.run_until_drained()
    eng.pop_done()
    return [tuple(s.result().tokens) for s in streams]


def main(quick: bool = False):
    cfg = reduced(get_config("stablelm-3b")).replace(act="gelu")
    n_req = 4 if quick else 12
    policy = QuantPolicy(num_layers=cfg.num_layers, mode="int",
                         last_k_int4=cfg.num_layers)
    params = api.init_model(cfg, jax.random.PRNGKey(0))

    # ---- reference streams: plain single-device engine
    ref_model = deploy(params, ExecutionPlan.build(
        cfg, policy, backend="reference", kv_bits=8))
    ref = _burst(ServingEngine(ref_model, slots=2, max_len=64), cfg, n_req)
    print(f"[tp=1] {n_req} requests, first stream: "
          f"{[int(t) for t in ref[0]]}")

    # ---- tensor parallel: build at tp=2, save, reshard on load
    if jax.device_count() >= 2:
        plan = ExecutionPlan.build(cfg, policy, backend="reference",
                                   kv_bits=8, tp=2)
        model = deploy(params, plan)
        with tempfile.TemporaryDirectory() as d:
            model.save(d)
            for tp in (2, 1) + ((4,) if jax.device_count() >= 4 else ()):
                # warmup=True pre-compiles the (bucket, n) ladder so the
                # first request pays steady-state latency
                eng = ServingEngine(DeployedModel.load(d, tp=tp), slots=2,
                                    max_len=64, warmup=True)
                got = _burst(eng, cfg, n_req)
                assert got == ref, f"tp={tp} diverged from tp=1"
                s = eng.metrics.summary()
                print(f"[tp={tp}] streams byte-identical to tp=1; "
                      f"decode first {s['decode_first_ms']:.1f}ms vs "
                      f"steady p50 {s.get('decode_steady_p50_ms', 0):.1f}ms")
    else:
        print(f"[tp] skipped: host exposes {jax.device_count()} device(s); "
              "set XLA_FLAGS=--xla_force_host_platform_device_count=8")

    # ---- data parallel: 2 engines, one admission queue, same streams
    rs = ReplicaSet(ref_model, replicas=2, slots=2, max_len=64)
    got = _burst(rs, cfg, n_req)
    assert got == ref, "replica set diverged from single engine"
    print(f"[replicas=2] {n_req} requests over {rs.replicas} engines, "
          "streams byte-identical to the single engine")
    print("OK")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    main(**vars(p.parse_args()))
