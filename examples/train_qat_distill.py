"""End-to-end driver: QAT-train a ~100M-param LM for a few hundred steps
with the full MKQ recipe (MSE-based LSQ + MINI distillation from a deeper
fp teacher), fault-tolerant checkpointing included.

This is deliverable (b)'s "train ~100M model for a few hundred steps" —
sized for this CPU container via --scale (default 'small' ~ 4M params;
pass --scale 100m on real hardware; the code path is identical).

Run:  PYTHONPATH=src python examples/train_qat_distill.py --steps 200
"""
import argparse

from repro.configs import TrainHParams, get_config, reduced
from repro.core.policy import QuantPolicy
from repro.data import lm_batches
from repro.launch.train import run_training


def configs(scale: str):
    base = get_config("stablelm-3b")
    if scale == "100m":
        student = base.replace(num_layers=12, d_model=768, num_heads=12,
                               num_kv_heads=12, d_ff=2048, vocab_size=32000,
                               dtype="float32", remat=False)
        teacher = student.replace(num_layers=16, d_model=1024, num_heads=16,
                                  num_kv_heads=16, d_ff=2816)
    else:
        student = reduced(base)
        teacher = student.replace(num_layers=6, d_model=96, num_heads=6,
                                  num_kv_heads=6, d_ff=192, head_dim=16)
    return student, teacher


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--scale", default="small", choices=["small", "100m"])
    p.add_argument("--grad-mode", default="mse", choices=["mse", "ste"])
    p.add_argument("--ckpt-dir", default="/tmp/repro_qat_distill")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    args = p.parse_args()

    cfg, tcfg = configs(args.scale)
    n = cfg.num_layers
    policy = QuantPolicy(num_layers=n, mode="fake", last_k_int4=n // 2,
                         grad_mode=args.grad_mode)
    hp = TrainHParams(total_steps=args.steps, lr_weights=5e-4, alpha=10.0,
                      beta=1.0)
    data = lm_batches(cfg.vocab_size, args.seq, args.batch)

    # fp teacher: a few warm-up steps on the same stream (stands in for a
    # pretrained checkpoint — no downloads in this container)
    print("[example] training fp teacher briefly...")
    tpolicy = QuantPolicy(num_layers=tcfg.num_layers, mode="none")
    tstate, _ = run_training(tcfg, tpolicy, TrainHParams(
        total_steps=max(50, args.steps // 4), lr_weights=1e-3),
        iter(data), ckpt_dir=args.ckpt_dir + "_teacher", ckpt_every=0,
        log_every=25)
    teacher = tstate["params"]

    print(f"[example] QAT ({args.grad_mode}) + MINI distillation...")
    state, metrics = run_training(
        cfg, policy, hp, iter(data), ckpt_dir=args.ckpt_dir, ckpt_every=50,
        distill_teacher=teacher, teacher_cfg=tcfg, log_every=20)
    print("[example] final metrics:", metrics)


if __name__ == "__main__":
    main()
