"""Serve a quantized model with batched, streaming requests (the paper's
deployment).

The deployment flow (DESIGN.md §9): build an ``ExecutionPlan`` (segments +
kernel selection + KV precision resolved once), ``deploy()`` the packed
int4/int8 ``DeployedModel``, ``save()`` it, then serve the RELOADED artifact
through the continuous-batching engine (``repro.serving``, DESIGN.md §7) —
chunked prefill, slot-isolated KV cache, latency metrics. The serve side
never touches fp weights and never recalibrates, and its token streams are
byte-identical to serving the in-memory model (asserted below).

The generation API (DESIGN.md §10) on display here:

* greedy ``GenerationRequest`` bursts drained via ``run_until_drained`` and
  ``pop_done()`` (no unbounded done-list growth);
* a sampled request (temperature/top-k/seed) iterated token-by-token through
  its ``TokenStream`` — same tokens every run, per-request determinism;
* a stop-token request that releases its slot early.

Pass backend="pallas" to route matmuls through the int4/int8 Pallas kernels
(fused dequant+bias+GELU decode epilogue; interpret mode off-TPU).

Run:  PYTHONPATH=src python examples/serve_int4.py [--quick]
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.deploy import DeployedModel, ExecutionPlan, deploy
from repro.models import api
from repro.serving import GenerationRequest, SamplingParams, ServingEngine


def _burst(eng, cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        plen = int(rng.integers(4, 16))
        eng.submit(GenerationRequest(
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=8))
    steps = eng.run_until_drained()
    return steps, {r.rid: r.out.tolist() for r in eng.pop_done()}


def main(quick: bool = False):
    cfg = reduced(get_config("qwen2.5-32b"))
    n = cfg.num_layers
    n_requests = 4 if quick else 12
    policy = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    # kv_bits=8 stores the KV cache as int8 codes + per-(token, head)
    # scales (DESIGN.md §8) — 4 packs int4 nibbles, 16 keeps fp rows
    plan = ExecutionPlan.build(cfg, policy, kv_bits=8)

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    model = deploy(params, plan)
    n_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(model.params))
    n_fp = sum(x.size * 4 for x in jax.tree.leaves(params))
    print(f"deployed weights: {n_bytes/1e6:.2f}MB vs fp32 {n_fp/1e6:.2f}MB "
          f"({n_fp/n_bytes:.1f}x reduction)")

    # serve the in-memory model, then the saved+reloaded artifact: identical
    eng = ServingEngine(model, slots=4, max_len=128)
    t0 = time.time()
    steps, mem_streams = _burst(eng, cfg, n_requests)
    dt = time.time() - t0
    toks = sum(len(v) for v in mem_streams.values())
    print(f"served {len(mem_streams)} requests / {toks} tokens in {steps} "
          f"engine steps, {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    print("metrics:", eng.metrics.report())

    with tempfile.TemporaryDirectory() as td:
        loaded = DeployedModel.load(model.save(f"{td}/artifact"))
    eng2 = loaded.engine(slots=4, max_len=128)
    _, art_streams = _burst(eng2, cfg, n_requests)
    assert art_streams == mem_streams, "artifact streams diverged!"
    print(f"artifact round trip: {len(art_streams)} requests byte-identical")
    print("sample output:", art_streams[0])

    # --- streaming + sampling (DESIGN.md §10): iterate tokens as produced
    stream = eng2.submit(GenerationRequest(
        prompt=np.array([5, 9, 2, 7], np.int32), max_new_tokens=8,
        sampling=SamplingParams(temperature=0.8, top_k=40, seed=42)))
    sampled = [tok for tok in stream]      # pumps the engine under the hood
    print(f"sampled stream (T=0.8, top_k=40, seed=42): {sampled} "
          f"[{stream.finish_reason}]")

    # --- stop tokens: the request ends the moment it emits one, freeing
    # its slot for queued work instead of decoding to max_new_tokens
    stop = eng2.submit(GenerationRequest(
        prompt=np.array([5, 9, 2, 7], np.int32), max_new_tokens=64,
        stop_tokens={sampled[2]},      # same seed → same stream → stops early
        sampling=SamplingParams(temperature=0.8, top_k=40, seed=42)))
    r = stop.result()
    assert r.finish_reason == "stop" and len(r.tokens) <= 3, r
    print(f"stop-token request: {len(r.tokens)}/64 tokens "
          f"[{r.finish_reason}] — slot released early")
    eng2.pop_done()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: smaller burst")
    main(quick=ap.parse_args().quick)
