"""Serve a quantized model with batched requests (the paper's deployment).

Builds an int4/int8 deployed model (calibrate -> pack), spins up the
continuous-batching engine from ``repro.serving`` (DESIGN.md §7) — chunked
prefill + slot-isolated KV cache + latency metrics — submits a burst of
requests and reports throughput. On TPU, pass use_pallas=True to
api.segments_for to route the matmuls through the int4/int8 Pallas kernels
(with the fused dequant+bias+GELU decode epilogue on gelu-FFN archs).

Run:  PYTHONPATH=src python examples/serve_int4.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import QuantPolicy
from repro.core.qat import (calibrate_weight_scales, default_bits_fn,
                            deploy_params)
from repro.serving import Request, ServingEngine
from repro.models import api


def main():
    cfg = reduced(get_config("qwen2.5-32b"))
    n = cfg.num_layers
    policy = QuantPolicy(num_layers=n, mode="int", last_k_int4=n // 2)
    segments = api.segments_for(cfg, policy)

    params = api.init_model(cfg, jax.random.PRNGKey(0))
    params = calibrate_weight_scales(params, default_bits_fn(cfg, policy))
    deployed = deploy_params(params, cfg, segments)
    n_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(deployed))
    n_fp = sum(x.size * 4 for x in jax.tree.leaves(params))
    print(f"deployed weights: {n_bytes/1e6:.2f}MB vs fp32 {n_fp/1e6:.2f}MB "
          f"({n_fp/n_bytes:.1f}x reduction)")

    # kv_bits=8 stores the KV cache as int8 codes + per-(token, head)
    # scales (DESIGN.md §8) — pass 4 for packed int4 nibbles, 16 for fp rows
    eng = ServingEngine(deployed, cfg, segments, slots=4, max_len=128,
                        kv_bits=8)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(12):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(prompt=rng.integers(1, cfg.vocab_size, plen)
                           .astype(np.int32), max_new_tokens=8))
    steps = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in eng.done)
    print(f"served {len(eng.done)} requests / {toks} tokens in {steps} "
          f"engine steps, {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    print("metrics:", eng.metrics.report())
    print("sample output:", eng.done[0].out.tolist())


if __name__ == "__main__":
    main()
